"""Shared neural-net building blocks: RMSNorm, RoPE / M-RoPE, SwiGLU, MoE.

All modules are pure functions over explicit parameter pytrees:
`init_*(rng, cfg) -> params` and `apply(params, x, ...) -> y`. Layer stacks
live in `backbone.py`; blocked attention in `attention.py`.

Conventions
-----------
- Activations flow in `cfg.dtype` (bf16 by default); reductions that need
  range (softmax, norms, router) are computed in fp32 and cast back.
- Every init uses truncated-normal-ish scaled init; exact init statistics
  are not a paper contribution, determinism is (seeded PRNG keys).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import AttentionConfig, ModelConfig, MoEConfig


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution-mode knobs threaded through model code.

    static_unroll=True replaces `lax.scan` layer/Q-block loops with Python
    loops so that XLA cost analysis counts every iteration (the "cost"
    dry-run mode); scan mode keeps the HLO small (the "proof" mode and
    real execution).
    """

    static_unroll: bool = False
    q_block: int = 1024          # attention query-block length
    use_kernels: bool = False    # route hot ops through Pallas kernels
    remat: bool = True           # checkpoint scan bodies during training
    moe_group_size: int = 4096   # tokens per MoE dispatch group
    # Megatron-style sequence parallelism: PartitionSpec entries (as a
    # tuple) to constrain the (B, S, D) residual stream at layer
    # boundaries, e.g. (("pod", "data"), "model", None). Shards the saved
    # remat carries over the model axis (16x activation-memory reduction
    # on the production mesh - EXPERIMENTS.md §Perf iteration 2).
    carry_spec: tuple | None = None
    # Expert-parallel axes for the MoE expert dim (must divide num_experts;
    # set by the launch factories from the mesh). When set, the dispatch
    # buffers are re-laid out expert-major (one all-to-all each way) so the
    # expert FFN einsum is fully local - without it XLA all-gathers the
    # expert weight banks every layer (EXPERIMENTS.md §Perf iteration 4).
    ep_axes: tuple | None = None


DEFAULT_EXEC = ExecConfig()


def constrain_carry(x, exec_cfg: "ExecConfig"):
    if exec_cfg.carry_spec is None:
        return x
    from jax.sharding import PartitionSpec

    return jax.lax.with_sharding_constraint(x, PartitionSpec(*exec_cfg.carry_spec))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.float32)


def rmsnorm(g: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """fp32 only inside the mean-square reduction; the normalize multiply
    stays in the input dtype. Upcasting the whole tensor would make the
    surrounding sequence-parallel collectives (and their cotangents) run
    in fp32 - 2x the wire bytes (EXPERIMENTS.md §Perf iteration 4)."""
    dt = x.dtype
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(ms + eps).astype(dt)
    return x * scale * g.astype(dt)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------
def rope_angles(
    positions: jax.Array,          # (..., S) int32 or (3, ..., S) for m-rope
    head_dim: int,
    theta: float,
    m_rope_sections: Optional[tuple[int, int, int]] = None,
):
    """Return (sin, cos) of shape (..., S, head_dim/2), fp32.

    For M-RoPE (qwen2-vl), `positions` has a leading axis of 3 (temporal,
    height, width) and the rotary frequencies are split into the three
    sections: frequency i uses the position stream of its section.
    """
    half = head_dim // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if m_rope_sections is None:
        ang = positions.astype(jnp.float32)[..., None] * inv_freq
        return jnp.sin(ang), jnp.cos(ang)
    t, h, w = m_rope_sections
    assert t + h + w == half, f"m_rope sections {m_rope_sections} != {half}"
    # section id per frequency: 0 for temporal, 1 height, 2 width
    sec = jnp.concatenate(
        [jnp.zeros((t,), jnp.int32), jnp.ones((h,), jnp.int32), 2 * jnp.ones((w,), jnp.int32)]
    )
    # positions: (3, ..., S) -> (..., S, half) selecting stream per freq
    pos = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_per_freq = jnp.take(pos, sec, axis=-1)                # (..., S, half)
    ang = pos_per_freq * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, D). sin/cos: (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if sin.ndim == 2:
        s = sin[None, :, None, :]
        c = cos[None, :, None, :]
    else:
        s = sin[:, :, None, :]
        c = cos[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_swiglu(rng: jax.Array, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (routed top-k + optional shared experts)
#
# Sort-free capacity dispatch: tokens are grouped (group = `moe_group_size`
# contiguous tokens), each (token, k) unit is assigned a slot
# `expert * C + rank` where rank is the unit's arrival order within its
# expert (computed with a scatter-add bincount + argsort rank), units with
# rank >= C are dropped (standard capacity dropping). Expert FFNs then run
# as one batched einsum over (G, E, C, D) - no (T, E, C) one-hot tensors,
# so memory stays O(tokens) and FLOPs stay O(active params).
# ---------------------------------------------------------------------------
def init_moe(rng: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dtype = _dtype(cfg)
    k_router, k_e1, k_e2, k_e3, k_sh = jax.random.split(rng, 5)
    s_in = d ** -0.5
    s_out = m.d_ff_expert ** -0.5
    p = {
        "router": (jax.random.normal(k_router, (d, m.num_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k_e1, (m.num_experts, d, m.d_ff_expert)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k_e2, (m.num_experts, d, m.d_ff_expert)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k_e3, (m.num_experts, m.d_ff_expert, d)) * s_out).astype(dtype),
    }
    if m.num_shared_experts > 0:
        p["shared"] = init_swiglu(k_sh, d, m.d_ff_shared, dtype)
    return p


def _moe_capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(c, m.top_k)


def moe_ffn(
    p: dict,
    x: jax.Array,                 # (B, S, D)
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> jax.Array:
    m = cfg.moe
    b, s, d = x.shape
    total = b * s
    tg = min(exec_cfg.moe_group_size, total)
    assert total % tg == 0, f"tokens {total} not divisible by group {tg}"
    g = total // tg

    # Sharding anchors: groups are data-local by construction (contiguous
    # token blocks), so pin the group dim to the batch axes and the expert
    # FFN hidden dim to "model". Without these, SPMD propagation through
    # the dispatch scatter replicates the expert buffers (167 GiB/device on
    # llama4-scout train_4k - EXPERIMENTS.md §Perf iteration 3).
    if exec_cfg.carry_spec is not None:
        from jax.sharding import PartitionSpec as P

        dp, tp = exec_cfg.carry_spec[0], exec_cfg.carry_spec[1]
        dp = dp if isinstance(dp, tuple) else (dp,)
        gspec = dp if g % 32 == 0 else None  # divisible by dp on both meshes
        ep = exec_cfg.ep_axes
        anchor2 = lambda t: jax.lax.with_sharding_constraint(t, P(gspec, None, None))
        if ep is not None:
            # expert-major layout: experts on the EP axes, expert FFN local.
            # When EP uses only part of the batch axes, the group dim keeps
            # the rest - leaving an axis unused replicates the buffers
            # across it (§Perf iteration 7: 57 GiB on multi-pod qwen2-moe).
            rest = tuple(a for a in (gspec or ()) if a not in ep) or None
            anchor_h = lambda t: jax.lax.with_sharding_constraint(t, P(rest, ep, None, tp))
            anchor_o = lambda t: jax.lax.with_sharding_constraint(t, P(rest, ep, None, None))
        else:
            anchor_h = lambda t: jax.lax.with_sharding_constraint(t, P(gspec, None, None, tp))
            anchor_o = lambda t: jax.lax.with_sharding_constraint(t, P(gspec, None, None, None))
    else:
        anchor2 = anchor_h = anchor_o = lambda t: t

    xg = anchor2(x.reshape(g, tg, d))

    # --- routing (fp32 on the small (T, E) logits only) ---
    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, m.top_k)              # (G, Tg, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renormalize

    tk = tg * m.top_k
    flat_e = expert_idx.reshape(g, tk)                            # (G, TK)
    cap = _moe_capacity(tg, m)

    # All group-local scatter/gathers are vmapped 1-D ops: vmap emits
    # operand-batching dims that GSPMD partitions trivially over the
    # group axis (explicit iota-index scatters got replicated instead -
    # EXPERIMENTS.md §Perf iteration 3).
    # rank of each (token, k) unit within its expert, via stable argsort
    sort_idx = jnp.argsort(flat_e, axis=-1, stable=True)          # (G, TK)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    counts = jax.vmap(
        lambda fe: jnp.zeros((m.num_experts,), jnp.int32).at[fe].add(1))(flat_e)
    offsets = jnp.cumsum(counts, axis=-1) - counts                # exclusive
    rank_sorted = jnp.arange(tk)[None, :] - jnp.take_along_axis(offsets, sorted_e, axis=-1)
    # invert the permutation: rank[sort_idx[j]] = rank_sorted[j]
    rank = jax.vmap(
        lambda si, rs: jnp.zeros((tk,), jnp.int32).at[si].set(rs))(sort_idx, rank_sorted)

    keep = rank < cap                                             # capacity drop
    slot = jnp.where(keep, flat_e * cap + rank, m.num_experts * cap)  # overflow slot

    # --- dispatch: scatter tokens into (G, E*C (+1 overflow), D) buffers ---
    token_of_unit = jnp.arange(tk) // m.top_k                     # (TK,)
    xu = jnp.take(xg, token_of_unit, axis=1)                      # (G, TK, D)
    buf = anchor2(jax.vmap(
        lambda sl, xr: jnp.zeros((m.num_experts * cap + 1, d), xg.dtype).at[sl].set(xr)
    )(slot, xu))
    ein = anchor_o(buf[:, : m.num_experts * cap].reshape(g, m.num_experts, cap, d))

    # --- expert computation: batched swiglu over experts ---
    hgate = anchor_h(jnp.einsum("gecd,edf->gecf", ein, p["w_gate"]))
    hup = anchor_h(jnp.einsum("gecd,edf->gecf", ein, p["w_up"]))
    hout = anchor_o(jnp.einsum("gecf,efd->gecd", jax.nn.silu(hgate) * hup, p["w_down"]))
    hflat = anchor2(jnp.concatenate(
        [hout.reshape(g, m.num_experts * cap, d), jnp.zeros((g, 1, d), hout.dtype)], axis=1
    ))

    # --- combine: gather each unit's expert output, weight by gate ---
    out_u = anchor2(jax.vmap(lambda hf, sl: jnp.take(hf, sl, axis=0))(hflat, slot))
    w = (gate.reshape(g, tk) * keep).astype(out_u.dtype)
    out = (out_u * w[..., None]).reshape(g, tg, m.top_k, d).sum(axis=2)

    if m.num_shared_experts > 0:
        out = out + swiglu(p["shared"], xg)
    return out.reshape(b, s, d)


def moe_aux_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style f*P dot product).

    The router matmul runs in the activation dtype and only the tiny
    (T, E) logits are upcast - upcasting the (T, D) activations would
    put a second fp32 consumer on the embedding output and drag every
    residual-stream cotangent (and its collectives) to fp32."""
    m = cfg.moe
    d = cfg.d_model
    logits = (x.reshape(-1, d) @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embed(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    k1, k2 = jax.random.split(rng)
    p = {"embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(k2, (cfg.vocab_size, cfg.d_model)) * cfg.d_model ** -0.5
        ).astype(dtype)
    return p


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embed"], tokens, axis=0)


def lm_logits(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p.get("lm_head", p["embed"])
    return x @ w.T
