from repro.models.config import (
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    reduced,
)
from repro.models.layers import ExecConfig, DEFAULT_EXEC
from repro.models.backbone import (
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    serve_step,
)

__all__ = [
    "AttentionConfig", "ModelConfig", "MoEConfig", "RWKVConfig", "SSMConfig",
    "reduced", "ExecConfig", "DEFAULT_EXEC", "forward", "init_cache",
    "init_params", "loss_fn", "prefill", "serve_step",
]
