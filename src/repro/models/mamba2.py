"""Mamba2 (SSD) mixer for the Zamba2 hybrid (arXiv:2411.15242).

Selective state-space recurrence (per head, state N, head channels P):
    h_t = a_t h_{t-1} + dt_t * B_t x_t^T         h in R^{N x P},  a_t = exp(A dt_t)
    y_t = C_t^T h_t + D * x_t

Chunked SSD form mirrors rwkv6.py: intra-chunk work is batched einsums
(fully counted by XLA cost analysis); the inter-chunk state recurrence is a
small `lax.scan`. Scalar-per-head decays make the log-space factorization
exact; per-step log-decays are clamped to [-DECAY_CLAMP, 0] and intra-chunk
factors centered at half the chunk total, bounding exponents by
DECAY_CLAMP * chunk / 2 = 64 (fp32-safe).

B and C are shared across heads (n_groups=1), matching Zamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ExecConfig, DEFAULT_EXEC, rmsnorm

DECAY_CLAMP = 1.0


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return d_inner, nheads, conv_ch


def init_mamba2(rng: jax.Array, cfg: ModelConfig) -> dict:
    """Per-segment projections (z / x / B / C / dt kept as separate weights
    so each shards cleanly on the tensor-model axis - a fused in_proj would
    put segment boundaries inside shards)."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, _ = dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 7)
    sc = d ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d, d_inner)) * sc).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, d_inner)) * sc).astype(dtype),
        "w_b": (jax.random.normal(ks[2], (d, s.state_dim)) * sc).astype(dtype),
        "w_c": (jax.random.normal(ks[3], (d, s.state_dim)) * sc).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d, nheads)) * sc).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (s.conv_width, d_inner)) * 0.5).astype(dtype),
        "conv_b": (jax.random.normal(ks[6], (s.conv_width, s.state_dim)) * 0.5).astype(dtype),
        "conv_c": (jax.random.normal(ks[6], (s.conv_width, s.state_dim)) * 0.5).astype(dtype),
        "conv_bias_x": jnp.zeros((d_inner,), dtype),
        "conv_bias_b": jnp.zeros((s.state_dim,), dtype),
        "conv_bias_c": jnp.zeros((s.state_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, nheads)).astype(jnp.float32),  # A = -exp(a_log)
        "dt_bias": jnp.full((nheads,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) * d_inner ** -0.5).astype(dtype),
    }


def causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array | None = None):
    """Depthwise causal conv, width W. xbc: (B, T, C), w: (W, C).

    `prev` is the (B, W-1, C) tail of the previous segment (decode carry);
    returns (out, new_prev)."""
    width = w.shape[0]
    bsz, t, c = xbc.shape
    if prev is None:
        prev = jnp.zeros((bsz, width - 1, c), xbc.dtype)
    padded = jnp.concatenate([prev, xbc], axis=1)
    out = sum(padded[:, i : i + t] * w[i] for i in range(width)) + b
    return jax.nn.silu(out), padded[:, -(width - 1) :]


def ssd_chunked(
    xh: jax.Array,    # (B, T, H, P)
    b_in: jax.Array,  # (B, T, N)  shared across heads
    c_in: jax.Array,  # (B, T, N)
    dt: jax.Array,    # (B, T, H)  fp32, post-softplus
    a_log: jax.Array,  # (H,)
    state0: jax.Array | None = None,  # (B, H, N, P) fp32
    chunk: int = 128,
):
    """Chunked SSD scan. Returns (y (B,T,H,P) fp32, final_state)."""
    bsz, t, h, p = xh.shape
    n = b_in.shape[-1]
    if t % chunk:
        # pad to a chunk multiple: dt=0 kills both the state update and the
        # decay (la = -exp(a_log)*0 = 0), making the padding exact.
        pad = chunk - t % chunk
        p4 = [(0, 0), (0, pad), (0, 0), (0, 0)]
        p3 = [(0, 0), (0, pad), (0, 0)]
        y, state = ssd_chunked(
            jnp.pad(xh, p4), jnp.pad(b_in, p3), jnp.pad(c_in, p3),
            jnp.pad(dt, p3), a_log, state0, chunk)
        return y[:, :t], state
    nc = t // chunk
    # intra-chunk tensors stay in the activation dtype (bf16 in-model;
    # exponents are fp32-computed then cast - bf16 shares fp32's exponent
    # range so the centered factors cannot overflow). Only the cumulative
    # decays and the carried state stay fp32. Halves the per-layer backward
    # workspace (EXPERIMENTS.md §Perf iteration 6).
    cdt = xh.dtype
    la = jnp.clip(-jnp.exp(a_log) * dt, -DECAY_CLAMP, 0.0)  # (B,T,H) f32
    la = la.reshape(bsz, nc, chunk, h)
    dtc = dt.reshape(bsz, nc, chunk, h)
    xc = xh.reshape(bsz, nc, chunk, h, p)
    bc = b_in.reshape(bsz, nc, chunk, n)
    cc = c_in.reshape(bsz, nc, chunk, n)

    cum = jnp.cumsum(la, axis=2)                       # inclusive (B,nc,Lc,H)
    m = cum[:, :, -1]                                  # (B,nc,H)
    half = 0.5 * m[:, :, None]

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    c_f = cc[..., None, :] * jnp.exp(cum - half)[..., None].astype(cdt)
    b_f = bc[..., None, :] * (jnp.exp(half - cum) * dtc)[..., None].astype(cdt)
    scores = jnp.einsum("bcihn,bcjhn->bchij", c_f, b_f)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))    # inclusive diagonal
    scores = jnp.where(mask[None, None, None], scores, jnp.zeros((), scores.dtype))
    y = jnp.einsum("bchij,bcjhp->bcihp", scores, xc,
                   preferred_element_type=jnp.float32)

    # inter-chunk state recurrence
    if state0 is None:
        state0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    c_st = cc[..., None, :] * jnp.exp(cum)[..., None].astype(cdt)  # from h0
    b_st = bc[..., None, :] * (jnp.exp(m[:, :, None] - cum) * dtc)[..., None].astype(cdt)

    def step(s, inp):
        c_c, b_c, x_c, m_c = inp
        y_state = jnp.einsum("blhn,bhnp->blhp", c_c.astype(jnp.float32), s)
        s = s * jnp.exp(m_c)[..., None, None] + jnp.einsum(
            "blhn,blhp->bhnp", b_c.astype(jnp.float32), x_c.astype(jnp.float32))
        return s, y_state

    xs = tuple(jnp.moveaxis(zz, 1, 0) for zz in (c_st, b_st, xc, m))
    state, y_state = jax.lax.scan(step, state0, xs)
    y = y + jnp.moveaxis(y_state, 0, 1)
    return y.reshape(bsz, t, h, p), state


def ssd_step(
    xh: jax.Array,    # (B, H, P)
    b_in: jax.Array,  # (B, N)
    c_in: jax.Array,  # (B, N)
    dt: jax.Array,    # (B, H) fp32
    a_log: jax.Array,
    state: jax.Array,  # (B, H, N, P) fp32
):
    la = jnp.clip(-jnp.exp(a_log) * dt, -DECAY_CLAMP, 0.0)
    xf = xh.astype(jnp.float32)
    upd = jnp.einsum("bn,bhp->bhnp", b_in.astype(jnp.float32), xf * dt[..., None])
    state = state * jnp.exp(la)[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), state)
    return y, state


def mamba2_block(
    p: dict,
    x: jax.Array,                  # (B, T, D)
    cfg: ModelConfig,
    state0: jax.Array | None = None,
    conv_prev: jax.Array | None = None,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
):
    """Full-sequence Mamba2 block. Returns (out, (ssm_state, conv_state))."""
    s = cfg.ssm
    d_inner, nheads, _ = dims(cfg)
    bsz, t, _ = x.shape
    z = x @ p["w_z"]
    dt_raw = x @ p["w_dt"]
    if conv_prev is None:
        cp_x = cp_b = cp_c = None
    else:
        cp_x, cp_b, cp_c = jnp.split(conv_prev, [d_inner, d_inner + s.state_dim], axis=-1)
    xs, cs_x = causal_conv(x @ p["w_x"], p["conv_x"], p["conv_bias_x"], cp_x)
    b_in, cs_b = causal_conv(x @ p["w_b"], p["conv_b"], p["conv_bias_b"], cp_b)
    c_in, cs_c = causal_conv(x @ p["w_c"], p["conv_c"], p["conv_bias_c"], cp_c)
    conv_state = jnp.concatenate([cs_x, cs_b, cs_c], axis=-1)
    xh = xs.reshape(bsz, t, nheads, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if exec_cfg.use_kernels:
        from repro.kernels import ops as kops

        y, state = kops.mamba2_ssd(xh, b_in, c_in, dt, p["a_log"], state0, chunk=s.chunk_size)
    else:
        y, state = ssd_chunked(xh, b_in, c_in, dt, p["a_log"], state0, chunk=s.chunk_size)
    y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, t, d_inner)
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (state, conv_state)


def mamba2_step(
    p: dict,
    x: jax.Array,                  # (B, D)
    state: jax.Array,              # (B, H, N, P)
    conv_prev: jax.Array,          # (B, W-1, C)
    cfg: ModelConfig,
):
    s = cfg.ssm
    d_inner, nheads, _ = dims(cfg)
    bsz = x.shape[0]
    z = x @ p["w_z"]
    dt_raw = x @ p["w_dt"]
    cp_x, cp_b, cp_c = jnp.split(conv_prev, [d_inner, d_inner + s.state_dim], axis=-1)
    xs, cs_x = causal_conv((x @ p["w_x"])[:, None], p["conv_x"], p["conv_bias_x"], cp_x)
    b_in, cs_b = causal_conv((x @ p["w_b"])[:, None], p["conv_b"], p["conv_bias_b"], cp_b)
    c_in, cs_c = causal_conv((x @ p["w_c"])[:, None], p["conv_c"], p["conv_bias_c"], cp_c)
    conv_state = jnp.concatenate([cs_x, cs_b, cs_c], axis=-1)
    xs, b_in, c_in = xs[:, 0], b_in[:, 0], c_in[:, 0]
    xh = xs.reshape(bsz, nheads, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, state = ssd_step(xh, b_in, c_in, dt, p["a_log"], state)
    y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, d_inner)
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (state, conv_state)
