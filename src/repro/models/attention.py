"""Attention: blocked causal prefill/train attention + single-token decode.

Memory discipline is the point here: a 32k-token prefill must never
materialize the full (B, H, S, S) score tensor. The blocked form iterates
over query blocks; each step materializes only (B, H, q_block, S) scores.
In scan mode the Q-block loop is a `lax.scan` with a checkpointed body so
that the *backward* pass also stays O(q_block) (flash-style recompute); in
static_unroll (cost) mode it is a Python loop with *static causal slicing*
of K/V so HLO FLOPs reflect the causal ~S^2/2 work.

The Pallas flash-attention kernel (kernels/flash_attention.py) implements
the same contract for the TPU hot path; `exec_cfg.use_kernels` routes to it.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import AttentionConfig, ModelConfig
from repro.models.layers import ExecConfig, DEFAULT_EXEC, apply_rope, rope_angles

NEG_INF = -1e30


def init_attention(rng: jax.Array, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    a = cfg.attn
    d = d_model or cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    kq, kk, kv, ko = jax.random.split(rng, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(kq, (d, a.q_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, a.kv_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, a.kv_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (a.q_dim, d)) * (a.q_dim ** -0.5)).astype(dtype),
    }


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, H, D), k: (B, Sk, KV, D) -> scores (B, KV, H/KV, Sq, Sk)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, sq, kvh, h // kvh, d)
    return jnp.einsum("bsqgd,btqd->bqgst", q, k) * (d ** -0.5)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B, KV, G, Sq, Sk), v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, kvh, g, sq, _ = probs.shape
    o = jnp.einsum("bqgst,btqd->bsqgd", probs, v)
    return o.reshape(b, sq, kvh * g, -1)


def _attend_block(
    q: jax.Array,            # (B, qb, H, D)
    k: jax.Array,            # (B, Sk, KV, D)
    v: jax.Array,
    q_offset: jax.Array,     # scalar: global position of q[0]
    causal: bool,
) -> jax.Array:
    scores = _gqa_scores(q, k).astype(jnp.float32)
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_out(probs, v)


def multihead_attention(
    q: jax.Array,            # (B, S, H, D)  (already RoPE'd)
    k: jax.Array,            # (B, S, KV, D)
    v: jax.Array,
    cfg_attn: AttentionConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> jax.Array:
    """Full-sequence causal attention, blocked over query blocks."""
    b, s, h, d = q.shape
    qb = min(exec_cfg.q_block, s)
    if exec_cfg.use_kernels:
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, causal=cfg_attn.causal)
    if s <= qb:
        return _attend_block(q, k, v, jnp.int32(0), cfg_attn.causal)
    if s % qb:
        # pad queries to a block multiple; padded rows are discarded
        pad = qb - s % qb
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return multihead_attention(qp, k, v, cfg_attn, exec_cfg)[:, :s]
    nblocks = s // qb

    if exec_cfg.static_unroll:
        # Python loop + static causal slicing of K/V: HLO carries the true
        # causal FLOP count (~S^2/2) for the cost dry-run.
        outs = []
        for i in range(nblocks):
            hi = (i + 1) * qb
            outs.append(
                _attend_block(
                    q[:, i * qb : hi],
                    k[:, :hi],
                    v[:, :hi],
                    jnp.int32(i * qb),
                    cfg_attn.causal,
                )
            )
        return jnp.concatenate(outs, axis=1)

    qblocks = q.reshape(b, nblocks, qb, h, d).swapaxes(0, 1)  # (nb, B, qb, H, D)

    def body(carry, inp):
        i, qi = inp
        out = _attend_block(qi, k, v, i * qb, cfg_attn.causal)
        return carry, out

    body = jax.checkpoint(body)  # flash-style: recompute scores in backward
    _, outs = jax.lax.scan(body, None, (jnp.arange(nblocks), qblocks))
    return outs.swapaxes(0, 1).reshape(b, s, h, d)


def decode_attention(
    q: jax.Array,            # (B, 1, H, D)
    k_cache: jax.Array,      # (B, KV, S_max, D)
    v_cache: jax.Array,
    pos: jax.Array,          # (B,) current lengths (q is at index pos)
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> jax.Array:
    """Single-token attention against a (padded) KV cache."""
    if exec_cfg.use_kernels:
        from repro.kernels import ops as kops

        return kops.decode_attention(q, k_cache, v_cache, pos)
    b, kvh, smax, d = k_cache.shape
    h = q.shape[2]
    g = h // kvh
    qh = q[:, 0].reshape(b, kvh, g, d)
    scores = jnp.einsum("bqgd,bqtd->bqgt", qh, k_cache).astype(jnp.float32) * (d ** -0.5)
    # Ragged-length mask: the cache is padded to the batch max (S_max), so
    # for every sequence shorter than S_max the tail slots hold arbitrary
    # *finite* garbage (stale tokens, zeros, or - on the paged path - the
    # pool's dump block). This mask is the ONLY thing excluding those slots:
    # NEG_INF substitution before the softmax drives their probability to
    # exactly 0.0 regardless of content. Garbage must stay finite (never
    # NaN): 0.0 * NaN = NaN would still poison the value einsum below.
    mask = jnp.arange(smax)[None, :] <= pos[:, None]              # (B, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bqgt,bqtd->bqgd", probs, v_cache)
    return out.reshape(b, 1, h, d)


def attention_block(
    p: dict,
    x: jax.Array,             # (B, S, D_model)
    positions: jax.Array,     # (B, S) or (3, B, S) for m-rope
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Projections + RoPE + causal attention. Returns (out, (k, v)) so the
    caller can populate a KV cache during prefill."""
    a = cfg.attn
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, a.num_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(b, s, a.num_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(b, s, a.num_kv_heads, a.head_dim)
    sin, cos = rope_angles(positions, a.head_dim, a.rope_theta, a.m_rope_sections)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    o = multihead_attention(q, k, v, a, exec_cfg)
    return o.reshape(b, s, -1) @ p["wo"], (k, v)


def attention_extend_block(
    p: dict,
    x: jax.Array,             # (B, K, D_model) - K new tokens
    k_cache: jax.Array,       # (B, KV, S_max, D)
    v_cache: jax.Array,
    pos: jax.Array,           # (B,) first new position
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked decode: K new tokens attend over prefix + themselves.

    Used by speculative-decoding verification (target model scores K draft
    tokens in one pass) and by continuation after rollback."""
    a = cfg.attn
    b, kk, _ = x.shape
    q = (x @ p["wq"]).reshape(b, kk, a.num_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(b, kk, a.num_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(b, kk, a.num_kv_heads, a.head_dim)
    prope = pos[:, None] + jnp.arange(kk, dtype=jnp.int32)[None, :]
    if a.m_rope_sections is not None:
        prope = jnp.broadcast_to(prope, (3, b, kk))
    sin, cos = rope_angles(prope, a.head_dim, a.rope_theta, a.m_rope_sections)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    def write(cache, new, p0):
        return jax.lax.dynamic_update_slice(cache, new, (0, p0, 0))

    k_cache = jax.vmap(write)(k_cache, k.transpose(0, 2, 1, 3), pos)
    v_cache = jax.vmap(write)(v_cache, v.transpose(0, 2, 1, 3), pos)

    kvh, smax = k_cache.shape[1], k_cache.shape[2]
    g = a.num_heads // kvh
    qh = q.reshape(b, kk, kvh, g, a.head_dim)
    scores = jnp.einsum("bsqgd,bqtd->bqgst", qh, k_cache).astype(jnp.float32) * (
        a.head_dim ** -0.5
    )
    qpos = pos[:, None] + jnp.arange(kk)[None, :]                  # (B, K)
    mask = jnp.arange(smax)[None, None, :] <= qpos[:, :, None]     # (B, K, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bqgst,bqtd->bsqgd", probs, v_cache).reshape(b, kk, -1)
    return o @ p["wo"], k_cache, v_cache


def attention_decode_block(
    p: dict,
    x: jax.Array,             # (B, 1, D_model)
    k_cache: jax.Array,       # (B, KV, S_max, D)
    v_cache: jax.Array,
    pos: jax.Array,           # (B,) position to write at / attend through
    positions_rope: jax.Array,  # (B, 1) or (3, B, 1)
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step: write new k/v at `pos`, attend over prefix."""
    a = cfg.attn
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, a.num_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(b, 1, a.num_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(b, 1, a.num_kv_heads, a.head_dim)
    sin, cos = rope_angles(positions_rope, a.head_dim, a.rope_theta, a.m_rope_sections)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # write new k/v at per-sequence position `pos` (scatter, not a full-cache
    # rewrite - decode is memory-bound, touching the whole cache twice would
    # double its HBM traffic).
    def write(cache, new, p):
        return jax.lax.dynamic_update_slice(cache, new, (0, p, 0))

    k_cache = jax.vmap(write)(k_cache, k.transpose(0, 2, 1, 3), pos)
    v_cache = jax.vmap(write)(v_cache, v.transpose(0, 2, 1, 3), pos)
    o = decode_attention(q, k_cache, v_cache, pos, exec_cfg)
    return o.reshape(b, 1, -1) @ p["wo"], k_cache, v_cache


def attention_paged_decode_block(
    p: dict,
    x: jax.Array,             # (B, 1, D_model)
    k_pages: jax.Array,       # (NBp, KV, bs, D) - one pool layer
    v_pages: jax.Array,
    tables: jax.Array,        # (B, NB) int32 dump-padded block tables
    lengths: jax.Array,       # (B,) cached tokens (new token's position)
    positions_rope: jax.Array,  # (B, 1)
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
    max_len: int = 0,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gather-free decode step against PagedKVPool storage.

    The dense path (`attention_decode_block`) needs the engine to gather
    each sequence's pages into a contiguous (B, KV, S_max, D) cache first;
    this variant hands the pool's page array + block tables straight to
    `kops.paged_decode_attention`, and returns the step's own (k, v) for
    the caller to `scatter_append` into the pool. m-RoPE is unsupported
    (the engine gates VLM families to the gather path)."""
    a = cfg.attn
    assert a.m_rope_sections is None, "paged decode does not support m-rope"
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, a.num_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(b, 1, a.num_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(b, 1, a.num_kv_heads, a.head_dim)
    sin, cos = rope_angles(positions_rope, a.head_dim, a.rope_theta, None)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    from repro.kernels import ops as kops

    o = kops.paged_decode_attention(
        q, k_pages, v_pages, tables, lengths, k, v, max_len=max_len, impl=impl)
    return o.reshape(b, 1, -1) @ p["wo"], k, v


def attention_paged_chunk_block(
    p: dict,
    x: jax.Array,             # (1, C, D_model) - one sequence's chunk
    k_pages: jax.Array,       # (NBp, KV, bs, D) - one pool layer
    v_pages: jax.Array,
    table: jax.Array,         # (NB,) int32 block table covering ctx0 tokens
    ctx0: int,                # static: cached tokens before this chunk
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused chunked prefill: C new tokens of one sequence attend over the
    sequence's paged cached context plus themselves (causal), without the
    engine re-running the backbone over the whole prefix. Returns
    (out, k, v) with k/v (1, C, KV, D) for `scatter_chunk`."""
    a = cfg.attn
    assert a.m_rope_sections is None, "paged prefill does not support m-rope"
    _, c, _ = x.shape
    q = (x @ p["wq"]).reshape(1, c, a.num_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(1, c, a.num_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(1, c, a.num_kv_heads, a.head_dim)
    positions = ctx0 + jnp.arange(c, dtype=jnp.int32)[None, :]    # (1, C)
    sin, cos = rope_angles(positions, a.head_dim, a.rope_theta, None)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    from repro.kernels import ops as kops

    o = kops.paged_prefill_attention(q, k_pages, v_pages, table, ctx0, k, v,
                                     impl=impl)
    return o.reshape(1, c, -1) @ p["wo"], k, v
