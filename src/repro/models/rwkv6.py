"""RWKV6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay, plus channel-mix FFN.

Recurrence (per head, head_dim = n):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T            S in R^{n x n}
    y_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})

Training/prefill uses a *chunked* parallel form (flash-linear-attention
style): all O(T * d^2) projection work and the O(T * Lc * d) intra-chunk
work are batched einsums (fully visible to XLA cost analysis); only the
O(T/Lc) inter-chunk state recurrence is a `lax.scan`, whose per-step
einsums are <1% of layer FLOPs (documented in DESIGN.md / roofline notes).

Numerical strategy: per-channel log-decays are clamped to
[-DECAY_CLAMP, -1e-4] and intra-chunk decay factors are centered at half
the chunk's total log-decay, bounding every exponent by
DECAY_CLAMP * chunk / 2 (fp32-safe for the default chunk of 16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ExecConfig, DEFAULT_EXEC, rmsnorm

DECAY_CLAMP = 8.0
CHUNK = 16  # fp32-safe with DECAY_CLAMP (exponents <= 8 * 16 / 2 = 64)

_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_time_mix(rng: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.rwkv
    h = d // r.head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 12)
    s = d ** -0.5
    return {
        "mu_x": jnp.zeros((d,), jnp.float32) + 0.5,
        "mus": jnp.full((5, d), 0.5, jnp.float32),
        "lora_mix_a": (jax.random.normal(ks[0], (d, 5, r.lora_dim_mix)) * s).astype(jnp.float32),
        "lora_mix_b": (jax.random.normal(ks[1], (5, r.lora_dim_mix, d)) * 0.01).astype(jnp.float32),
        "w0": jnp.full((d,), 0.5, jnp.float32),  # exp(0.5) ~ 1.65 decay rate
        "lora_w_a": (jax.random.normal(ks[2], (d, r.lora_dim_decay)) * s).astype(jnp.float32),
        "lora_w_b": (jax.random.normal(ks[3], (r.lora_dim_decay, d)) * 0.01).astype(jnp.float32),
        "wr": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[6], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[7], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[8], (d, d)) * s).astype(dtype),
        "u": (jax.random.normal(ks[9], (h, r.head_dim)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def init_channel_mix(rng: jax.Array, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "wv": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dtype),
        "wr": (jax.random.normal(k3, (d, d)) * d ** -0.5).astype(dtype),
    }


def _ddlerp(p: dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift interpolation -> the 5 mixed inputs."""
    dt = x.dtype
    xx = (x_prev - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    base = xf + xx * p["mu_x"]
    lora = jnp.einsum("...d,dkl->...kl", base, p["lora_mix_a"])
    lora = jnp.tanh(lora)
    dyn = jnp.einsum("...kl,kld->...kd", lora, p["lora_mix_b"])  # (..., 5, d)
    mixed = xf[..., None, :] + xx[..., None, :] * (p["mus"] + dyn)
    return [mixed[..., i, :].astype(dt) for i in range(5)]


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Per-channel log-decay log(w_t) in [-DECAY_CLAMP, -1e-4], fp32."""
    lw = jnp.tanh(xw.astype(jnp.float32) @ p["lora_w_a"]) @ p["lora_w_b"]
    rate = jnp.exp(jnp.clip(p["w0"] + lw, -6.0, jnp.log(DECAY_CLAMP)))
    return -jnp.clip(rate, 1e-4, DECAY_CLAMP)


def wkv_chunked(
    r: jax.Array,  # (B, T, H, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B, T, H, N) fp32, negative
    u: jax.Array,  # (H, N)
    state0: jax.Array | None = None,  # (B, H, N, N) fp32
    chunk: int = CHUNK,
):
    """Chunked WKV6. Returns (y (B,T,H,N) fp32, final_state)."""
    b, t, h, n = r.shape
    if t % chunk:
        # pad to a chunk multiple: k=0 (no state contribution), logw=0 (no
        # decay) makes the padding exact; padded outputs are sliced away.
        pad = chunk - t % chunk
        pz = [(0, 0), (0, pad), (0, 0), (0, 0)]
        y, state = wkv_chunked(
            jnp.pad(r, pz), jnp.pad(k, pz), jnp.pad(v, pz), jnp.pad(logw, pz),
            u, state0, chunk)
        return y[:, :t], state
    nc = t // chunk
    rf, kf, vf = (a.astype(jnp.float32).reshape(b, nc, chunk, h, n) for a in (r, k, v))
    lw = logw.reshape(b, nc, chunk, h, n)

    cum = jnp.cumsum(lw, axis=2)                      # inclusive, (B,nc,Lc,H,N)
    cum_ex = cum - lw                                  # exclusive
    m = cum[:, :, -1]                                  # (B,nc,H,N) chunk total
    half = 0.5 * m[:, :, None]

    # intra-chunk: scores_ij = sum_d r_i k_j exp(cum_ex_i - cum_j), j < i
    a_in = rf * jnp.exp(cum_ex - half)                 # exponents <= |m|/2
    b_in = kf * jnp.exp(half - cum)
    scores = jnp.einsum("bcihn,bcjhn->bchij", a_in, b_in)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y = jnp.einsum("bchij,bcjhn->bcihn", scores, vf)
    # diagonal (current-token) bonus term: (r_i . u k_i) v_i
    diag = jnp.einsum("bcihn,bcihn->bcih", rf, u * kf)
    y = y + diag[..., None] * vf

    # inter-chunk recurrence (state carry); per-step einsums are tiny
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    a_st = rf * jnp.exp(cum_ex)                        # for y_state = a @ S
    k_st = kf * jnp.exp(m[:, :, None] - cum)           # decayed to chunk end

    def step(S, inp):
        a_c, k_c, v_c, m_c = inp                       # (B,Lc,H,N)...(B,H,N)
        y_state = jnp.einsum("blhn,bhnm->blhm", a_c, S)
        S = S * jnp.exp(m_c)[..., None] + jnp.einsum("blhn,blhm->bhnm", k_c, v_c)
        return S, y_state

    xs = tuple(jnp.moveaxis(z, 1, 0) for z in (a_st, k_st, vf, m))
    state, y_state = jax.lax.scan(step, state0, xs)
    y = y + jnp.moveaxis(y_state, 0, 1)
    return y.reshape(b, t, h, n), state


def wkv_step(
    r: jax.Array,  # (B, H, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B, H, N) fp32
    u: jax.Array,  # (H, N)
    state: jax.Array,  # (B, H, N, N) fp32 (indexed [key_dim, value_dim])
):
    """One-token WKV6 recurrence (decode path)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    y = jnp.einsum("bhn,bhnm->bhm", rf, state + u[..., None] * kv)
    state = state * jnp.exp(logw)[..., None] + kv
    return y, state


def time_mix(
    p: dict,
    x: jax.Array,              # (B, T, D)
    x_prev: jax.Array,         # (B, D) carry from previous token (decode) or zeros
    state0: jax.Array | None,
    cfg: ModelConfig,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
):
    """Full-sequence time-mix. Returns (out, (last_x, final_state))."""
    b, t, d = x.shape
    r_cfg = cfg.rwkv
    h = d // r_cfg.head_dim
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, shifted)
    rr = (xr @ p["wr"]).reshape(b, t, h, r_cfg.head_dim)
    kk = (xk @ p["wk"]).reshape(b, t, h, r_cfg.head_dim)
    vv = (xv @ p["wv"]).reshape(b, t, h, r_cfg.head_dim)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _decay(p, xw).reshape(b, t, h, r_cfg.head_dim)
    if exec_cfg.use_kernels:
        from repro.kernels import ops as kops

        y, state = kops.rwkv6_wkv(rr, kk, vv, logw, p["u"], state0)
    else:
        y, state = wkv_chunked(rr, kk, vv, logw, p["u"], state0)
    y = y.reshape(b, t, d)
    y = rmsnorm(p["ln_x"], y, cfg.norm_eps).astype(x.dtype) * g
    return y @ p["wo"], (x[:, -1], state)


def time_mix_step(p: dict, x: jax.Array, x_prev: jax.Array, state: jax.Array, cfg: ModelConfig):
    """One-token time-mix. x: (B, D)."""
    b, d = x.shape
    r_cfg = cfg.rwkv
    h = d // r_cfg.head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    rr = (xr @ p["wr"]).reshape(b, h, r_cfg.head_dim)
    kk = (xk @ p["wk"]).reshape(b, h, r_cfg.head_dim)
    vv = (xv @ p["wv"]).reshape(b, h, r_cfg.head_dim)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _decay(p, xw).reshape(b, h, r_cfg.head_dim)
    y, state = wkv_step(rr, kk, vv, logw, p["u"], state)
    y = rmsnorm(p["ln_x"], y.reshape(b, d), cfg.norm_eps).astype(x.dtype) * g
    return y @ p["wo"], x, state


def channel_mix(p: dict, x: jax.Array, x_prev: jax.Array):
    """RWKV channel-mix. x: (B, T, D), x_prev: (B, D). Returns (out, last_x)."""
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = shifted - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), x[:, -1]


def channel_mix_step(p: dict, x: jax.Array, x_prev: jax.Array):
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), x
