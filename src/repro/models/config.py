"""Model configuration dataclasses + family registry.

One `ModelConfig` describes any architecture in the assigned pool:

  dense  - pre-norm GQA transformer (RoPE, SwiGLU)        glm4/granite/yi/llama
  moe    - dense backbone with routed-expert FFN          llama4-scout, qwen2-moe
  ssm    - RWKV6 "Finch" (attention-free)                 rwkv6-7b
  hybrid - Mamba2 blocks + shared attention taps          zamba2-2.7b
  audio  - decoder-only over EnCodec frames (stub front)  musicgen-medium
  vlm    - text backbone with M-RoPE (stub vision front)  qwen2-vl-72b

Everything downstream (init, forward, serve_step, sharding rules, roofline
analytics) is driven from this one dataclass.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int              # routed experts
    top_k: int
    d_ff_expert: int              # per-expert FFN hidden
    num_shared_experts: int = 0   # always-on experts
    d_ff_shared: int = 0          # total shared-expert hidden width
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer parameters."""

    state_dim: int = 64           # N: per-head SSM state size
    head_dim: int = 64            # P: channels per head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 128         # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" mixer parameters."""

    head_dim: int = 64
    lora_dim_decay: int = 64      # low-rank dim for data-dependent decay w_t
    lora_dim_mix: int = 32        # low-rank dim for token-shift mixing


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 1e6
    # M-RoPE (qwen2-vl): split of rotary dims into (temporal, height, width)
    # sections. None => standard 1-D RoPE.
    m_rope_sections: Optional[tuple[int, int, int]] = None
    causal: bool = True

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int                     # dense-FFN hidden (MoE: see moe.d_ff_expert)
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): one *shared-weight* attention block applied after every
    # `hybrid_attn_every` Mamba2 layers.
    hybrid_attn_every: int = 6
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # Modality frontend stub: None | "audio_frames" | "vision_patches".
    # Stubbed frontends feed precomputed (B, S, d_model) embeddings.
    frontend: Optional[str] = None
    max_seq_len: int = 524_288    # upper bound for RoPE tables etc.

    # ---------------- derived quantities ----------------
    def __post_init__(self):
        if self.family in ("dense", "moe", "audio", "vlm"):
            assert self.attn is not None, f"{self.name}: attention family needs attn cfg"
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "ssm":
            assert self.rwkv is not None
        if self.family == "hybrid":
            assert self.ssm is not None and self.attn is not None

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True for sub-quadratic archs (state-based decode): ssm + hybrid."""
        return self.family in ("ssm", "hybrid")

    @property
    def num_attn_layers(self) -> int:
        """Number of layers that hold a KV cache."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.num_layers // self.hybrid_attn_every
        return self.num_layers

    # -- parameter counting (used by roofline + carbon model) --
    def param_count(self) -> int:
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            a = self.attn
            per_layer += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
            if self.family == "moe":
                m = self.moe
                per_layer += d * m.num_experts        # router
                per_layer += m.num_experts * 3 * d * m.d_ff_expert
                if m.num_shared_experts:
                    per_layer += 3 * d * m.d_ff_shared
            else:
                per_layer += 3 * d * self.d_ff        # swiglu
            per_layer += 2 * d                        # norms
        elif self.family == "ssm":
            r = self.rwkv
            h = d // r.head_dim
            # time-mix: r/k/v/g/o projections + decay lora + mix loras + u
            per_layer += 5 * d * d
            per_layer += 2 * (d * r.lora_dim_decay + r.lora_dim_decay * d)
            per_layer += 5 * (d * r.lora_dim_mix + r.lora_dim_mix * d)
            per_layer += h * r.head_dim               # u (bonus)
            # channel-mix: k/v/r
            per_layer += d * self.d_ff + self.d_ff * d + d * d
            per_layer += 2 * d
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            # mamba2 block: in_proj (z,x,B,C,dt) + conv + out_proj
            per_layer += d * (2 * d_in + 2 * s.state_dim + nheads)
            per_layer += s.conv_width * (d_in + 2 * s.state_dim)
            per_layer += d_in * d
            per_layer += nheads * 3                   # A, D, dt_bias
            per_layer += 3 * d * self.d_ff            # swiglu ffn
            per_layer += 2 * d
        n += per_layer * self.num_layers
        if self.family == "hybrid":
            a = self.attn
            n += 2 * self.d_model * a.q_dim + 2 * self.d_model * a.kv_dim  # shared attn (applied at taps)
        n += d                                        # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        dense_experts = m.num_experts * 3 * d * m.d_ff_expert
        active_experts = m.top_k * 3 * d * m.d_ff_expert
        return self.param_count() - self.num_layers * (dense_experts - active_experts)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes appended per generated/prefilled token (GQA-aware).

        This is the quantity that drives the Disg-Pref-Decode interconnect
        wall (paper Fig. 4): the whole prefix's KV must cross the link.
        """
        if self.family == "ssm":
            return 0  # constant state, nothing per token
        a = self.attn
        return self.num_attn_layers * 2 * a.num_kv_heads * a.head_dim * dtype_bytes

    def state_bytes(self, dtype_bytes: int = 4) -> int:
        """Constant-size recurrent state per sequence (ssm/hybrid)."""
        if self.family == "ssm":
            r = self.rwkv
            h = self.d_model // r.head_dim
            return self.num_layers * h * r.head_dim * r.head_dim * dtype_bytes
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * self.d_model
            nheads = d_in // s.head_dim
            conv = s.conv_width * (d_in + 2 * s.state_dim)
            return self.num_layers * (nheads * s.head_dim * s.state_dim + conv) * dtype_bytes
        return 0

    def flops_per_token(self, seq_len: int = 0) -> float:
        """Approximate forward FLOPs/token: 2*N_active + attention term."""
        f = 2.0 * self.active_param_count()
        if self.attn is not None and self.family != "ssm":
            layers = self.num_attn_layers
            a = self.attn
            f += 4.0 * layers * a.num_heads * a.head_dim * max(seq_len, 1)
        return f


def head_dim_of(d_model: int, num_heads: int) -> int:
    hd = d_model // num_heads
    assert hd * num_heads == d_model
    return hd


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
    )
    if cfg.attn is not None:
        kv = min(cfg.attn.num_kv_heads, 2)
        heads = max(2, min(4, cfg.attn.num_heads))
        heads = max(heads, kv) - (max(heads, kv) % kv)
        small["attn"] = dataclasses.replace(
            cfg.attn,
            num_heads=max(heads, kv),
            num_kv_heads=kv,
            head_dim=128 // max(heads, kv) if 128 % max(heads, kv) == 0 else 32,
        )
        # keep d_model = heads*head_dim relationship simple: use 4 heads x 32
        small["attn"] = dataclasses.replace(
            small["attn"], num_heads=4, num_kv_heads=min(kv, 4), head_dim=32
        )
        if cfg.attn.m_rope_sections is not None:
            small["attn"] = dataclasses.replace(
                small["attn"], m_rope_sections=(8, 4, 4)
            )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=128,
            d_ff_shared=128 if cfg.moe.num_shared_experts else 0,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32, chunk_size=32)
    if cfg.rwkv is not None:
        small["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32, lora_dim_decay=16, lora_dim_mix=8)
    if cfg.family == "hybrid":
        small["hybrid_attn_every"] = 2
        small["num_layers"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
