"""Training step factories: sharded pjit step, microbatch accumulation,
and a compressed-gradient data-parallel variant.

The plain step relies on XLA SPMD for all communication (reduce-scatter /
all-reduce placement chosen by the partitioner from the in/out shardings);
the compressed variant does the data-axis gradient sync explicitly in
shard_map with int8 payloads (distributed/compression.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compression import compress_tree_mean
from repro.distributed.sharding import (
    batch_pspecs,
    param_pspecs,
    zero_pspecs,
)
from repro.models import backbone
from repro.models.config import ModelConfig
from repro.models.layers import DEFAULT_EXEC, ExecConfig
from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state


def loss_and_grads(params, batch, cfg: ModelConfig, exec_cfg: ExecConfig,
                   microbatches: int = 1):
    """Value+grad with optional microbatch gradient accumulation."""
    if microbatches <= 1:
        return jax.value_and_grad(backbone.loss_fn)(params, batch, cfg, exec_cfg)

    b = batch["labels"].shape[0]
    assert b % microbatches == 0, (b, microbatches)
    mb = b // microbatches

    def _split(path, x):
        name = str(path[-1].key) if path else ""
        if name == "positions":  # (3, B, S): batch is dim 1
            y = x.reshape(x.shape[0], microbatches, mb, *x.shape[2:])
            return jnp.moveaxis(y, 1, 0)
        return x.reshape(microbatches, mb, *x.shape[1:])

    split = jax.tree_util.tree_map_with_path(_split, batch)

    def one(carry, mbatch):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(backbone.loss_fn)(params, mbatch, cfg, exec_cfg)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, g_acc), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(one, (jnp.zeros(()), zero), split)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def train_step(params, opt_state, batch, cfg: ModelConfig,
               opt_cfg: AdamWConfig = AdamWConfig(),
               exec_cfg: ExecConfig = DEFAULT_EXEC,
               microbatches: int = 1):
    loss, grads = loss_and_grads(params, batch, cfg, exec_cfg, microbatches)
    # pin the gradient cross-replica sync to bf16: the optimizer consumes
    # fp32, and without this barrier XLA hoists the upcast above the
    # data-axis all-reduce - 2x the wire bytes (§Perf iteration 4)
    grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    params, opt_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
    return params, opt_state, {"loss": loss, **metrics}


def make_sharded_train_step(
    mesh: Mesh,
    cfg: ModelConfig,
    params_like,
    batch_like,
    opt_cfg: AdamWConfig = AdamWConfig(),
    exec_cfg: ExecConfig = DEFAULT_EXEC,
    microbatches: int = 1,
    donate: bool = True,
):
    """jit(train_step) with explicit in/out shardings for `mesh`.

    params: TP-sharded ("model"); optimizer state: additionally ZeRO-sharded
    over the data axes; batch: sharded over ("pod", "data")."""
    pspec = param_pspecs(params_like, mesh)
    zspec = zero_pspecs(params_like, mesh)
    bspec = batch_pspecs(batch_like, mesh)
    opt_spec = {"step": P(), "m": zspec, "v": zspec, "master": zspec}
    metric_spec = {"loss": P(), "grad_norm": P(), "lr": P()}

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    fn = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                           exec_cfg=exec_cfg, microbatches=microbatches)
    return jax.jit(
        fn,
        in_shardings=(ns(pspec), ns(opt_spec), ns(bspec)),
        out_shardings=(ns(pspec), ns(opt_spec), ns(metric_spec)),
        donate_argnums=(0, 1) if donate else (),
    )


def make_compressed_train_step(
    mesh: Mesh,
    cfg: ModelConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    exec_cfg: ExecConfig = DEFAULT_EXEC,
    data_axis: str = "data",
):
    """Data-parallel step with explicit int8 gradient all-reduce.

    Params are replicated over `data_axis`; each shard computes grads on
    its batch slice; the sync is the int8 error-feedback all-reduce. State
    carries the per-shard residual."""
    from jax.experimental.shard_map import shard_map

    def step(params, opt_state, residual, batch):
        def shard_fn(params, opt_state, residual, batch):
            residual = jax.tree.map(lambda r: r[0], residual)  # drop shard dim
            loss, grads = jax.value_and_grad(backbone.loss_fn)(
                params, batch, cfg, exec_cfg)
            grads, residual = compress_tree_mean(grads, data_axis, residual)
            loss = jax.lax.pmean(loss, data_axis)
            params, opt_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
            residual = jax.tree.map(lambda r: r[None], residual)
            return params, opt_state, residual, {"loss": loss, **metrics}

        rep = P()
        bspec = jax.tree.map(lambda _: P(data_axis), batch)
        rspec = jax.tree.map(lambda _: P(data_axis), residual)  # per-shard state
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params),
                      jax.tree.map(lambda _: rep, opt_state),
                      rspec, bspec),
            out_specs=(jax.tree.map(lambda _: rep, params),
                       jax.tree.map(lambda _: rep, opt_state),
                       rspec,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
            check_rep=False,
        )(params, opt_state, residual, batch)

    return jax.jit(step)


def init_residual(params, mesh: Mesh, data_axis: str = "data"):
    """Per-shard error-feedback residual (stacked over the data axis)."""
    n = mesh.shape[data_axis]
    return jax.tree.map(
        lambda p: jnp.zeros((n, *p.shape), jnp.float32), params)
