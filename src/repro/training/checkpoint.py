"""Step-atomic sharded checkpointing with restart and elastic reshard.

Layout:
    <dir>/step_00001230/
        manifest.json        {step, leaves: [{key, file, shape, dtype, crc32}]}
        leaf_000000.npy ...
    <dir>/LATEST             text file naming the newest complete step dir

Write protocol: leaves + manifest go into a `.tmp-<step>` directory which
is atomically renamed; LATEST is rewritten last (a crash leaves either the
old or new checkpoint fully intact, never a torn one). Every leaf carries
a CRC32 that restore verifies - a corrupted checkpoint is skipped and the
previous one is used (restore_latest walks backwards).

Elastic reshard: leaves are stored as full (unsharded) arrays, so
restoring onto a *different* mesh is just device_put with the new mesh's
shardings - the elastic trainer (training/elastic.py) uses this to resume
on fewer/more devices after a failure. On a multi-host deployment each
host writes its owned shards plus a shard-index in the manifest; this
container is single-host so leaves are written whole (the manifest schema
already carries the shard fields).
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in leaves]
    return keys, [leaf for _, leaf in leaves], treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    keys, leaves, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind not in "iufb":  # ml_dtypes (bfloat16 etc.): store raw
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = f"leaf_{i:06d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical_dtype,
            "crc32": zlib.crc32(arr.tobytes()),
            "shard": 0, "num_shards": 1,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):  # pragma: no cover - re-save same step
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, ".LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, ".LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return final


class CorruptCheckpoint(RuntimeError):
    pass


def _load_dir(path: str, like: Any, shardings: Optional[Any]) -> tuple[int, Any]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys, like_leaves, treedef = _flatten(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    arrays = []
    for key, leaf in zip(keys, like_leaves):
        e = by_key.get(key)
        if e is None:
            raise CorruptCheckpoint(f"{path}: missing leaf {key}")
        arr = np.load(os.path.join(path, e["file"]))
        if zlib.crc32(arr.tobytes()) != e["crc32"]:
            raise CorruptCheckpoint(f"{path}: CRC mismatch for {key}")
        if str(arr.dtype) != e["dtype"]:  # restore logical (e.g. bfloat16) view
            import ml_dtypes  # noqa: F401 - registers the dtypes

            arr = arr.view(np.dtype(e["dtype"]))
        arrays.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return manifest["step"], tree


def restore_latest(ckpt_dir: str, like: Any, shardings: Optional[Any] = None):
    """Restore the newest intact checkpoint (walks back past corrupt ones).

    Returns (step, tree) or (None, None) when nothing restorable exists."""
    if not os.path.isdir(ckpt_dir):
        return None, None
    candidates = sorted(
        (d for d in os.listdir(ckpt_dir) if d.startswith("step_")), reverse=True)
    latest_file = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(latest_file):
        with open(latest_file) as f:
            named = f.read().strip()
        if named in candidates:
            candidates.remove(named)
            candidates.insert(0, named)
    for cand in candidates:
        try:
            return _load_dir(os.path.join(ckpt_dir, cand), like, shardings)
        except (CorruptCheckpoint, FileNotFoundError, json.JSONDecodeError):
            continue
    return None, None
