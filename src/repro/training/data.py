"""Synthetic token data pipeline.

Deterministic, seekable batch stream (restart-safe: the iterator is
reconstructed from (seed, step) after checkpoint restore - no pipeline
state to snapshot). Batches are placed onto the mesh with the same specs
the train step expects, with an optional double-buffer prefetch.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import batch_pspecs
from repro.models.config import ModelConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int, step: int,
                    frontend: bool = False) -> dict:
    """One deterministic batch (numpy, host)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    out: dict = {}
    if cfg.frontend is not None or frontend:
        out["embeds"] = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32) * 0.02
    else:
        out["tokens"] = rng.integers(0, cfg.vocab_size, size=(batch, seq), dtype=np.int32)
    if cfg.attn is not None and cfg.attn.m_rope_sections is not None:
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
        out["positions"] = np.broadcast_to(pos, (3, batch, seq)).copy()
    out["labels"] = rng.integers(0, cfg.vocab_size, size=(batch, seq), dtype=np.int32)
    return out


class DataPipeline:
    """Infinite stream of device-placed batches, seekable by step."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0, dtype=jnp.bfloat16):
        self.cfg, self.mesh = cfg, mesh
        self.batch, self.seq, self.seed = batch, seq, seed
        self.step = start_step
        self.dtype = dtype
        example = synthetic_batch(cfg, batch, seq, seed, 0)
        specs = batch_pspecs(
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), example), mesh)
        self._shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    def seek(self, step: int) -> None:
        self.step = step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        host = synthetic_batch(self.cfg, self.batch, self.seq, self.seed, self.step)
        host = {k: (v.astype(self.dtype) if v.dtype == np.float32 else v)
                for k, v in host.items()}
        self.step += 1
        return jax.device_put(host, self._shardings)
