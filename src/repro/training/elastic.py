"""Elastic training runner: checkpoint/restart + re-mesh on node loss.

The runner owns the (mesh, params, opt_state, data) quartet and exposes a
step loop that survives injected failures: on a `NodeFailure`, it rebuilds
a mesh from the surviving devices (largest usable data-parallel degree),
restores the newest intact checkpoint (training/checkpoint.py leaves are
full arrays, so resharding onto the new mesh is a device_put), seeks the
data pipeline to the restored step, and continues. This is the same
protocol a 1000-node deployment runs on a hardware failure - there the
checkpoint shards live on a distributed store and the re-mesh comes from
the cluster scheduler, behind the same interfaces.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import param_pspecs, zero_pspecs
from repro.models import backbone
from repro.models.config import ModelConfig
from repro.models.layers import DEFAULT_EXEC, ExecConfig
from repro.training import checkpoint as ckpt
from repro.training.data import DataPipeline
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_sharded_train_step


class NodeFailure(RuntimeError):
    """Raised (or injected) when devices drop out mid-run."""


def _usable_mesh(devices, model_axis: int) -> Mesh:
    """Largest (data, model) mesh over the surviving devices."""
    n = len(devices)
    model_axis = min(model_axis, n)
    while n % model_axis:
        model_axis -= 1
    data = n // model_axis
    devs = np.asarray(devices[: data * model_axis]).reshape(data, model_axis)
    return Mesh(devs, ("data", "model"))


@dataclasses.dataclass
class ElasticTrainer:
    cfg: ModelConfig
    batch: int
    seq: int
    ckpt_dir: str
    opt_cfg: AdamWConfig = AdamWConfig()
    exec_cfg: ExecConfig = DEFAULT_EXEC
    model_axis: int = 1
    ckpt_every: int = 10
    seed: int = 0

    def __post_init__(self):
        self.devices = list(jax.devices())
        self.mesh: Optional[Mesh] = None
        self.step = 0
        self._build(restore=True)

    # ------------------------------------------------------------------
    def _build(self, restore: bool) -> None:
        self.mesh = _usable_mesh(self.devices, self.model_axis)
        params = backbone.init_params(jax.random.PRNGKey(self.seed), self.cfg)
        opt = init_opt_state(params)
        pshard = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                              param_pspecs(params, self.mesh))
        zspec = zero_pspecs(params, self.mesh)
        oshard = {
            "step": NamedSharding(self.mesh, jax.sharding.PartitionSpec()),
            "m": jax.tree.map(lambda s: NamedSharding(self.mesh, s), zspec),
            "v": jax.tree.map(lambda s: NamedSharding(self.mesh, s), zspec),
            "master": jax.tree.map(lambda s: NamedSharding(self.mesh, s), zspec),
        }
        if restore:
            got_step, state = ckpt.restore_latest(
                self.ckpt_dir, {"params": params, "opt": opt},
                {"params": pshard, "opt": oshard})
            if got_step is not None:
                self.step = got_step
                params, opt = state["params"], state["opt"]
            else:
                params = jax.device_put(params, pshard)
                opt = jax.device_put(opt, oshard)
        else:  # pragma: no cover
            params = jax.device_put(params, pshard)
            opt = jax.device_put(opt, oshard)
        self.params, self.opt = params, opt
        self.pipeline = DataPipeline(self.cfg, self.mesh, self.batch, self.seq,
                                     seed=self.seed, start_step=self.step)
        example = next(iter(self.pipeline))
        self.pipeline.seek(self.step)
        self._step_fn = make_sharded_train_step(
            self.mesh, self.cfg, params, example, self.opt_cfg, self.exec_cfg,
            donate=False)

    # ------------------------------------------------------------------
    def fail_devices(self, n: int) -> None:
        """Simulate losing the last n devices; triggers a re-mesh + restore."""
        if n >= len(self.devices):
            raise ValueError("cannot lose every device")
        self.devices = self.devices[: len(self.devices) - n]
        self._build(restore=True)

    def run(self, steps: int, on_step: Optional[Callable] = None,
            fail_at: Optional[dict[int, int]] = None) -> list[float]:
        """Run `steps` more steps; `fail_at={step: n_devices}` injects
        failures. Returns the loss history (restarts visible as re-runs)."""
        losses = []
        target = self.step + steps
        while self.step < target:
            if fail_at and self.step in fail_at:
                n = fail_at.pop(self.step)
                self.fail_devices(n)
                continue
            batch = next(self.pipeline)
            self.params, self.opt, metrics = self._step_fn(self.params, self.opt, batch)
            self.step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            if on_step:
                on_step(self.step, metrics)
            if self.step % self.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, self.step,
                          {"params": self.params, "opt": self.opt})
        return losses
