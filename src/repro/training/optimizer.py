"""AdamW with fp32 master weights + moments (mixed-precision training).

Optimizer state is ZeRO-sharded over the data axes by the train-step
factory (distributed/sharding.zero_pspecs); this module is sharding-
agnostic pure math.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return m, v, w

    flat = jax.tree.map(upd, grads, state["m"], state["v"], state["master"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    new_state = {"step": step, "m": m, "v": v, "master": master}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
