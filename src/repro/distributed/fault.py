"""Fault tolerance utilities for the serving path.

`StragglerPolicy` implements deadline-based re-dispatch: an iteration that
exceeds `multiple x` its expected duration is assumed stuck (preempted
node, thermal throttle) and its work is re-issued to the backup pool; the
first result wins. The simulator applies it per decode iteration; a real
deployment applies it per pool RPC.

`HeartbeatTracker` is the liveness layer the elastic trainer consumes: a
pool that misses `miss_limit` heartbeats is declared failed, triggering
re-mesh (training/elastic.py) or pool eviction (serving router).

`FaultEvent`/`FaultTrace`/`FaultInjector` describe scripted failures that
both executors (ReplicaSim and ServingEngine) and the vector fleet core
consume.  Three kinds:

- ``kill``:    the replica dies at ``at_s``; every in-flight request is
               aborted (blocks freed, retained prefix-cache shed) and work
               already charged stays charged.
- ``preempt``: spot preemption with a notice window — the replica stops
               admitting at ``at_s`` and dies at ``at_s + notice_s``.  A
               standalone replica treats it as a delayed kill; the
               autoscale controller additionally drains during the notice.
- ``stall``:   for ``duration_s`` after ``at_s`` each step straggles with
               probability ``p_straggle`` (duration dilated by
               ``straggle_factor``, bounded by ``StragglerPolicy``
               mitigation).  Stalls stretch wall-clock only — the roofline
               busy/energy charge is unchanged (the chip is waiting, not
               re-computing), so energy monotonicity is preserved.

Semantics are aligned with the non-preemptive iteration model: faults take
effect at scheduling points, never mid-step, so a step that began before
the fault completes and its charge is kept exactly once.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

import numpy as np

_FAULT_RNG_TAG = 0x57A11  # dedicated stream: never perturbs acceptance rng


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    multiple: float = 3.0         # deadline = multiple x expected duration
    redispatch_overhead_s: float = 2e-3

    def deadline(self, expected_s: float) -> float:
        return self.multiple * expected_s

    def mitigate(self, actual_s: float, expected_s: float, backup_s: float) -> float:
        """Observed iteration time under the policy: when the primary blows
        its deadline, the re-dispatched backup bounds the tail."""
        d = self.deadline(expected_s)
        if actual_s <= d:
            return actual_s
        return d + self.redispatch_overhead_s + backup_s


@dataclasses.dataclass
class HeartbeatTracker:
    interval_s: float = 1.0
    miss_limit: int = 3
    _last: dict[str, float] = dataclasses.field(default_factory=dict)

    def beat(self, node: str, now_s: float) -> None:
        self._last[node] = now_s

    def dead(self, now_s: float) -> list[str]:
        limit = self.interval_s * self.miss_limit
        return [n for n, t in self._last.items() if now_s - t > limit]


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault. ``replica`` is a fleet-level index; single-replica
    consumers ignore it (the caller slices the trace per replica first)."""
    at_s: float
    kind: str                    # "kill" | "preempt" | "stall"
    replica: int = 0
    notice_s: float = 0.0        # preempt: grace before the node vanishes
    duration_s: float = 0.0      # stall: window length
    p_straggle: float = 0.25     # stall: per-step straggle probability
    straggle_factor: float = 10.0

    def __post_init__(self):
        if self.kind not in ("kill", "preempt", "stall"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at_s < 0:
            raise ValueError("at_s must be >= 0")
        if self.notice_s < 0 or self.duration_s < 0:
            raise ValueError("notice_s/duration_s must be >= 0")
        if not (0.0 <= self.p_straggle <= 1.0):
            raise ValueError("p_straggle must be in [0, 1]")

    @property
    def effective_kill_s(self) -> float:
        """Time the replica actually vanishes (inf for stall events)."""
        if self.kind == "kill":
            return self.at_s
        if self.kind == "preempt":
            return self.at_s + self.notice_s
        return math.inf


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """An immutable, time-sorted script of faults for a fleet."""
    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events", tuple(sorted(self.events, key=lambda e: e.at_s)))

    def for_replica(self, idx: int) -> tuple:
        return tuple(e for e in self.events if e.replica == idx)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


class FaultInjector:
    """Per-replica fault consumer.

    Holds the replica's slice of a `FaultTrace` plus a dedicated rng stream
    for stall sampling (isolated from the acceptance/workload streams, so a
    zero-fault trace replays schedules bit-exactly)."""

    def __init__(self, events: Iterable[FaultEvent] = (),
                 policy: Optional[StragglerPolicy] = None, seed: int = 0):
        evs = sorted(events, key=lambda e: e.at_s)
        self.events = tuple(evs)
        self.policy = policy if policy is not None else StragglerPolicy()
        kills = [e.effective_kill_s for e in evs if e.kind in ("kill", "preempt")]
        self.kill_s: float = min(kills) if kills else math.inf
        self._stalls = tuple(e for e in evs if e.kind == "stall")
        self._rng = np.random.default_rng((seed, _FAULT_RNG_TAG))

    def __bool__(self) -> bool:
        return bool(self.events)

    def notice_windows(self) -> list:
        """(notice_start_s, kill_s) per preempt event — controller use."""
        return [(e.at_s, e.effective_kill_s)
                for e in self.events if e.kind == "preempt"]

    def _stall_at(self, t: float) -> Optional[FaultEvent]:
        for e in self._stalls:
            if e.at_s <= t < e.at_s + e.duration_s:
                return e
        return None

    def step_time(self, at_s: float, base_s: float) -> float:
        """Wall-clock duration of a step that begins at ``at_s``.

        This is the single stall code path shared by both executors: the
        step's roofline charge (busy_s/energy_j) is priced as usual and the
        *clock* advances by the value returned here."""
        ev = self._stall_at(at_s)
        if ev is None:
            return base_s
        return apply_straggler_model(
            self._rng, base_s, self.policy,
            p_straggle=ev.p_straggle, straggle_factor=ev.straggle_factor)


def make_injector(faults, seed: int = 0,
                  policy: Optional[StragglerPolicy] = None):
    """Normalize a ctor arg: None | FaultInjector | iterable[FaultEvent]."""
    if faults is None:
        return None
    if isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultTrace):
        faults = faults.events
    evs: Sequence[FaultEvent] = tuple(faults)
    if not evs:
        return None
    return FaultInjector(evs, policy=policy, seed=seed)


def apply_straggler_model(
    rng, base_time_s: float, policy: StragglerPolicy,
    p_straggle: float = 0.0, straggle_factor: float = 10.0,
) -> float:
    """Sample an iteration duration under a straggler process bounded by the
    mitigation policy.  This is the one stall code path on the serving side:
    `FaultInjector.step_time` routes every executor's step timing through it
    (the backup pool re-serves at the primary's expected speed, so the
    re-dispatch bound is `deadline + overhead + base`)."""
    t = base_time_s
    if p_straggle > 0 and rng.random() < p_straggle:
        t = base_time_s * straggle_factor
    return policy.mitigate(t, base_time_s, base_time_s)
