"""Fault tolerance utilities for the serving path.

`StragglerPolicy` implements deadline-based re-dispatch: an iteration that
exceeds `multiple x` its expected duration is assumed stuck (preempted
node, thermal throttle) and its work is re-issued to the backup pool; the
first result wins. The simulator applies it per decode iteration; a real
deployment applies it per pool RPC.

`HeartbeatTracker` is the liveness layer the elastic trainer consumes: a
pool that misses `miss_limit` heartbeats is declared failed, triggering
re-mesh (training/elastic.py) or pool eviction (serving router).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    multiple: float = 3.0         # deadline = multiple x expected duration
    redispatch_overhead_s: float = 2e-3

    def deadline(self, expected_s: float) -> float:
        return self.multiple * expected_s

    def mitigate(self, actual_s: float, expected_s: float, backup_s: float) -> float:
        """Observed iteration time under the policy: when the primary blows
        its deadline, the re-dispatched backup bounds the tail."""
        d = self.deadline(expected_s)
        if actual_s <= d:
            return actual_s
        return d + self.redispatch_overhead_s + backup_s


@dataclasses.dataclass
class HeartbeatTracker:
    interval_s: float = 1.0
    miss_limit: int = 3
    _last: dict[str, float] = dataclasses.field(default_factory=dict)

    def beat(self, node: str, now_s: float) -> None:
        self._last[node] = now_s

    def dead(self, now_s: float) -> list[str]:
        limit = self.interval_s * self.miss_limit
        return [n for n, t in self._last.items() if now_s - t > limit]


def apply_straggler_model(
    rng, base_time_s: float, policy: StragglerPolicy | None,
    backup_time_s: float | None = None,
    p_straggle: float = 0.0, straggle_factor: float = 10.0,
) -> float:
    """Sample an iteration duration under an optional straggler process and
    an optional mitigation policy (used by the simulator sweeps)."""
    t = base_time_s
    if p_straggle > 0 and rng.random() < p_straggle:
        t = base_time_s * straggle_factor
    if policy is None:
        return t
    return policy.mitigate(t, base_time_s, backup_time_s or base_time_s)
