"""Gradient compression for the data-parallel sync path.

int8 all-reduce with a shared (pmax) scale: 1 byte/element on the wire
instead of 4, plus an error-feedback residual so quantization error does
not bias training (it is re-injected into the next step's gradients).

Used by training/train_step.make_compressed_train_step via shard_map over
the data axes. On the production mesh this composes with the "model" axis
left in auto mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_quantize(x: jax.Array, scale: jax.Array):
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def int8_allreduce_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over `axis_name` with int8 payloads (all-gather + local sum)."""
    n = jax.lax.psum(1, axis_name)
    local_max = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
    q = int8_quantize(x, scale)
    allq = jax.lax.all_gather(q, axis_name)           # int8 on the wire
    return allq.astype(jnp.float32).sum(axis=0) * scale / n


def compress_tree_mean(grads, axis_name: str, residual=None):
    """Compressed mean-all-reduce over a gradient pytree with error feedback.

    Returns (synced_grads, new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        synced = int8_allreduce_mean(g32, axis_name)
        # local quantization error (what this shard failed to communicate)
        local_max = jnp.max(jnp.abs(g32))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        err = g32 - int8_quantize(g32, scale).astype(jnp.float32) * scale
        return synced.astype(g.dtype), err

    pairs = jax.tree.map(one, grads, residual)
    synced = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return synced, new_res
