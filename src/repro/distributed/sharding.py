"""Logical-axis sharding rules for every parameter / cache / batch pytree.

Rules are name+shape driven (tree paths), so one function covers all six
architecture families:

  - vocab (embed / lm_head)            -> rows on "model"
  - attention q/k/v projections        -> columns (heads) on "model"
  - attention out / FFN down / out_proj-> rows on "model" (psum after)
  - FFN gate/up, MoE expert FFNs       -> hidden dim on "model"
  - RWKV/Mamba head-structured leaves  -> heads on "model" when divisible
  - small leaves (norm gains, biases, routers, loras, B/C projections)
                                       -> replicated
  - batch dims                         -> ("pod", "data")
  - decode KV caches                   -> sequence on "model" (flash-
    decoding style: most assigned archs have kv_heads not divisible by 16,
    so the robust rule shards the *sequence* and lets XLA insert the
    softmax partial-reduction), batch on data when divisible

ZeRO-style optimizer-state sharding: `zero_variant` adds the data axes to
the first replicated, divisible dimension of each leaf spec.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# version-portable shard_map: the experimental module is the home through
# jax 0.4.x; later releases promote it to jax.shard_map
try:
    from jax.experimental.shard_map import shard_map  # noqa: F401
except ImportError:  # pragma: no cover - newer jax
    shard_map = jax.shard_map  # noqa: F401


def make_abstract_mesh(shape: Sequence[int],
                       axis_names: Sequence[str]) -> AbstractMesh:
    """Version-portable AbstractMesh constructor.

    jax <= 0.4.37 takes one `((name, size), ...)` shape tuple; later
    releases take `(sizes, names)` positionally. Rules code should build
    meshes through this shim instead of calling AbstractMesh directly."""
    if len(shape) != len(axis_names):
        raise ValueError(f"shape {shape} vs axis_names {axis_names}")
    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))

# leaves that stay replicated regardless of shape (small / awkward to split)
_REPLICATED_NAMES = {
    "norm", "norm1", "norm2", "final_norm", "ln_x", "router", "mus", "mu_x",
    "mu_k", "mu_r", "w0", "lora_mix_a", "lora_mix_b", "lora_w_a", "lora_w_b",
    "conv_bias_x", "conv_bias_b", "conv_bias_c", "conv_b", "conv_c",
    "w_b", "w_c", "a_log", "dt_bias", "d_skip", "dt", "pos",
}
_ROW_SHARDED = {"embed", "lm_head", "wo", "w_down", "out_proj"}
_COL_SHARDED = {"wq", "wk", "wv", "wr", "wg", "w_gate", "w_up", "w_z", "w_x",
                "w_dt", "conv_x", "u", "wk_cm", "wv_cm"}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in _dp_axes(mesh)]) or 1)


def _leaf_name(path) -> str:
    return "/".join(
        str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)
    )


def _under_layers(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "layers" for e in path)


def _spec_for_param(name: str, shape: tuple[int, ...], mesh: Mesh, stacked: bool) -> P:
    model = _axis_size(mesh, "model")
    dp = _dp_axes(mesh)
    dps = _dp_size(mesh)
    ndim = len(shape)
    lead = 1 if stacked else 0

    base0 = name.split("/")[-1]
    if base0 in ("w_gate", "w_up", "w_down") and ndim - lead == 3:
        # MoE expert banks (E, D, F)/(E, F, D): expert-parallel over the
        # data axes when divisible (llama4: E=16), TP on the hidden dim.
        e_dim, mid, last = lead, lead + 1, lead + 2
        spec: list[Any] = [None] * ndim
        ep = ep_axes_for(mesh, shape[e_dim])
        if ep is not None:
            spec[e_dim] = ep if len(ep) > 1 else ep[0]
        h_dim = last if base0 != "w_down" else mid  # the FFN hidden dim
        if shape[h_dim] % model == 0:
            spec[h_dim] = "model"
        return P(*spec)

    def ok(dim_idx: int) -> bool:
        return shape[dim_idx] % model == 0 and shape[dim_idx] >= 256

    spec: list[Any] = [None] * ndim
    base = name.split("/")[-1]
    if base in _REPLICATED_NAMES or ndim == lead:
        return P(*spec)
    if name.endswith("channel_mix/wv"):
        # RWKV channel-mix down-projection: rows (hidden) on "model"
        if shape[lead] % model == 0:
            spec[lead] = "model"
        return P(*spec)
    if base in ("wk", "wv") and "attn" in name:
        # KV projections: shard heads only when every shard gets >= 1 head
        # (kv_heads >= model); otherwise replicate - the decode cache then
        # shards its *sequence* dim instead (cache_pspecs)
        if shape[-1] % model == 0 and shape[-1] // model >= 128:
            spec[-1] = "model"
        return P(*spec)
    if base in _ROW_SHARDED:
        # shard the first non-stack dim (rows); MoE w_down (E, F, D) -> F
        i = lead if shape[lead] % model == 0 and len(shape) - lead >= 2 else None
        if base == "w_down" and ndim - lead == 3:
            i = lead + 1
        if base in ("embed", "lm_head"):
            i = 0
        if i is not None and shape[i] % model == 0:
            spec[i] = "model"
        return P(*spec)
    if base in _COL_SHARDED:
        if shape[-1] % model == 0 and (shape[-1] >= 128 or base == "u"):
            spec[-1] = "model"
        return P(*spec)
    # default: replicate 1-D, column-shard >=2-D when divisible and large
    if ndim - lead >= 2 and ok(ndim - 1):
        spec[-1] = "model"
    return P(*spec)


def ep_axes_for(mesh: Mesh, num_experts: int):
    """Expert-parallel axes: the largest data-axes subset dividing E."""
    for axes in (("pod", "data"), ("data",), ("pod",)):
        if all(a in mesh.axis_names for a in axes):
            size = int(np.prod([_axis_size(mesh, a) for a in axes]))
            if size > 1 and num_experts % size == 0:
                return axes
    return None


def param_pspecs(params, mesh: Mesh):
    """PartitionSpec pytree matching a params pytree."""

    def assign(path, leaf):
        return _spec_for_param(_leaf_name(path), leaf.shape, mesh, _under_layers(path))

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_pspecs(batch, mesh: Mesh):
    """Shard global-batch dims over ("pod", "data")."""
    dp = _dp_axes(mesh)
    dps = _dp_size(mesh)

    def assign(path, leaf):
        name = _leaf_name(path)
        if name == "positions":                     # (3, B, S)
            return P(None, dp, None) if leaf.shape[1] % dps == 0 else P()
        b = leaf.shape[0]
        rest = [None] * (leaf.ndim - 1)
        if b % dps == 0:
            return P(dp, *rest)
        return P(None, *rest)

    return jax.tree_util.tree_map_with_path(assign, batch)


def cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh):
    """Decode-cache sharding. KV caches shard sequence on "model" and batch
    on the data axes when divisible; recurrent state shards heads on
    "model". Falls back to spreading the sequence over every axis for the
    B=1 long-context cells."""
    dp = _dp_axes(mesh)
    dps = _dp_size(mesh)
    model = _axis_size(mesh, "model")

    def assign(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            return P()
        if name in ("k", "v"):                      # (L, B, KV, S, hd)
            _, b, _, s, _ = leaf.shape
            if b % dps == 0 and s % model == 0:
                return P(None, dp, None, "model", None)
            if s % (dps * model) == 0:              # long-context, B == 1
                return P(None, None, None, dp + ("model",), None)
            return P()
        if name == "state":                         # rwkv (L, B, H, N, N)
            h = leaf.shape[2]
            bspec = dp if leaf.shape[1] % dps == 0 else None
            return P(None, bspec, "model" if h % model == 0 else None, None, None)
        if name == "ssm_state":                     # (L, B, H, N, P)
            h = leaf.shape[2]
            bspec = dp if leaf.shape[1] % dps == 0 else None
            return P(None, bspec, "model" if h % model == 0 else None, None, None)
        if name == "conv_state":                    # (L, B, W-1, C) mixed segs
            bspec = dp if leaf.shape[1] % dps == 0 else None
            return P(None, bspec, None, None)
        if name in ("x_prev_att", "x_prev_ffn"):    # (L, B, D)
            bspec = dp if leaf.shape[1] % dps == 0 else None
            return P(None, bspec, None)
        return P()

    return jax.tree_util.tree_map_with_path(assign, cache)


def tokens_pspec(tokens_shape, mesh: Mesh):
    dp = _dp_axes(mesh)
    if tokens_shape[0] % _dp_size(mesh) == 0:
        return P(dp, *([None] * (len(tokens_shape) - 1)))
    return P(*([None] * len(tokens_shape)))


# ---------------------------------------------------------------------------
# ZeRO-style optimizer-state sharding
# ---------------------------------------------------------------------------
def zero_variant(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Add the data axes to the first replicated, divisible dim of `spec`."""
    dp = _dp_axes(mesh)
    dps = _dp_size(mesh)
    if dps == 1:
        return spec
    used = {a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    if used & set(dp):   # already (expert-)sharded over the data axes
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (p, n) in enumerate(zip(parts, shape)):
        if p is None and n % dps == 0 and n >= dps:
            parts[i] = dp
            return P(*parts)
    return P(*parts)


def zero_pspecs(params, mesh: Mesh):
    specs = param_pspecs(params, mesh)
    return jax.tree.map(
        lambda leaf, s: zero_variant(s, leaf.shape, mesh), params, specs)
