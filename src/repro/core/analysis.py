"""Closed-form carbon-efficiency analysis of disaggregation (GreenLLM §5).

Two cases running the same LLM service:

  Case 1 (Standalone):     new chip A only      -> O_A + E_A
  Case 2 (Disaggregation): new chip A + old B   -> O'_A + E'_A + O_B + E_B

Assumptions (paper A.1-A.3): shared grid CI alpha; negligible communication
carbon; the extra time on A in case 2 is small vs B's busy time.

These closed forms are used by tests to cross-check the simulator, and by
`benchmarks/fig14_carbon_intensity.py` / `fig15_lifetime.py` to overlay
theory on measured sweeps.
"""
from __future__ import annotations

import dataclasses

from repro.core.carbon import J_PER_KWH


@dataclasses.dataclass(frozen=True)
class CaseInputs:
    """Inputs to the §5 analysis, all per-request (or per fixed work unit)."""

    # Case 1: standalone on new chip A.
    n_a: float       # energy on A, joules
    t_a: float       # busy time on A, seconds
    # Case 2: disaggregated on A (reduced role) + old chip B.
    n_a2: float      # energy on A in case 2, joules
    t_a2: float      # busy time on A in case 2, seconds
    n_b: float       # energy on B, joules
    t_b: float       # busy time on B, seconds
    # Chip embodied totals (gCO2) and lifetimes (seconds).
    emb_a_g: float
    emb_b_g: float
    life_a_s: float
    life_b_s: float


def _op(energy_j: float, alpha_g_per_kwh: float) -> float:
    return energy_j / J_PER_KWH * alpha_g_per_kwh


def _emb(t_s: float, emb_g: float, life_s: float) -> float:
    return t_s / life_s * emb_g


def standalone_carbon_g(c: CaseInputs, alpha: float) -> float:
    return _op(c.n_a, alpha) + _emb(c.t_a, c.emb_a_g, c.life_a_s)


def disaggregated_carbon_g(c: CaseInputs, alpha: float) -> float:
    return (
        _op(c.n_a2 + c.n_b, alpha)
        + _emb(c.t_a2, c.emb_a_g, c.life_a_s)
        + _emb(c.t_b, c.emb_b_g, c.life_b_s)
    )


def carbon_ratio(c: CaseInputs, alpha: float) -> float:
    """Eq. 5 LHS: (disaggregated total) / (standalone total). <1 means savings."""
    return disaggregated_carbon_g(c, alpha) / standalone_carbon_g(c, alpha)


def savings(c: CaseInputs, alpha: float) -> float:
    return 1.0 - carbon_ratio(c, alpha)


def energy_condition_holds(c: CaseInputs) -> bool:
    """Carbon Implication 1 (Eq. 4): disaggregation must consume less energy.

    Necessary condition for carbon savings under A.3 (the embodied-carbon
    delta of adding B is positive): N'_A + N_B < N_A.
    """
    return (c.n_a2 + c.n_b) < c.n_a


def ratio_decomposition(c: CaseInputs, alpha: float) -> tuple[float, float]:
    """Eq. 5 decomposition: ratio = energy_ratio + embodied_residual.

    Returns (energy_ratio, embodied_residual) where
      energy_ratio      = (N'_A + N_B) / N_A
      embodied_residual = (E'_A + E_B - energy_ratio * E_A) / (O_A + E_A)

    Carbon Implication 2: as alpha grows the residual shrinks toward 0, so
    the ratio tends to the energy ratio -> savings increase with alpha iff
    the energy condition (Eq. 4) holds.
    """
    e_a = _emb(c.t_a, c.emb_a_g, c.life_a_s)
    e_a2 = _emb(c.t_a2, c.emb_a_g, c.life_a_s)
    e_b = _emb(c.t_b, c.emb_b_g, c.life_b_s)
    o_a = _op(c.n_a, alpha)
    energy_ratio = (c.n_a2 + c.n_b) / c.n_a
    residual = (e_a2 + e_b - energy_ratio * e_a) / (o_a + e_a)
    return energy_ratio, residual


def lifetime_sensitivity(
    c: CaseInputs, alpha: float, *, new_life_s: float | None = None, old_life_s: float | None = None
) -> float:
    """Eq. 6 driver: carbon ratio with overridden lifetimes.

    Carbon Implication 3: ratio falls (savings rise) as old-chip lifetime
    T_B grows (its amortized embodied rate drops) and as new-chip lifetime
    T_A shrinks (standalone's embodied cost grows).
    """
    c2 = dataclasses.replace(
        c,
        life_a_s=new_life_s if new_life_s is not None else c.life_a_s,
        life_b_s=old_life_s if old_life_s is not None else c.life_b_s,
    )
    return carbon_ratio(c2, alpha)
