"""Disaggregation executors: the paper's component ① (§4.1).

Formalizes the two optimizers over a (new pool, old pool, interconnect)
triple and provides the standard configuration catalog the SLO-aware
scheduler searches over (§7.1):

    Standalone        target on new chip only (the carbon baseline)
    SpecDecode        colocated speculative decoding on the new chip
    DPD new+old       prefill on new, decode on old (KV crosses the link)
    DSD new+old+draft draft on old, target+verifier on new

`dsd_round_time` is the single source of truth for the Fig. 7
communication-overlap schedule, shared by the simulator and the engine.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.configs import get_config
from repro.models.config import ModelConfig
from repro.serving.perfmodel import Interconnect, dsd_round_time  # noqa: F401 (re-export)
from repro.serving.simulator import ServingMode


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """A fully-resolved serving configuration (mode + models + placement)."""

    mode: ServingMode
    target: ModelConfig
    draft: Optional[ModelConfig] = None

    @property
    def name(self) -> str:
        return self.mode.name


# Per-draft-model acceptance rates (profiled; the real-compute engine
# measures these end-to-end - serving/engine.py:acceptance_rate). Larger
# drafts track the target better.
DEFAULT_ACCEPTANCE = {"llama-1b": 0.7, "llama-300m": 0.55}


def standard_catalog(
    target: str = "llama-7b",
    drafts: tuple[str, ...] = ("llama-1b", "llama-300m"),
    new_chip: str = "a100",
    old_chips: tuple[str, ...] = ("t4", "v100"),
    interconnect: Interconnect = Interconnect(),
    spec_k: int = 4,
    acceptance: dict[str, float] | float | None = None,
) -> list[DisaggConfig]:
    """The paper's §7.1 configuration list (the scheduler's matrix columns)."""
    if acceptance is None:
        acceptance = DEFAULT_ACCEPTANCE
    acc = (lambda d: acceptance) if isinstance(acceptance, float) else \
        (lambda d: acceptance.get(d, 0.7))
    tcfg = get_config(target)
    out = [
        DisaggConfig(ServingMode("standalone", "standalone", new_chip,
                                 interconnect=interconnect), tcfg)
    ]
    for d in drafts:
        out.append(DisaggConfig(
            ServingMode(f"spec-{d}", "spec", new_chip, spec_k=spec_k,
                        acceptance=acc(d), interconnect=interconnect),
            tcfg, get_config(d)))
    for old in old_chips:
        out.append(DisaggConfig(
            ServingMode(f"dpd-{old}", "dpd", new_chip, old,
                        interconnect=interconnect), tcfg))
        for d in drafts:
            out.append(DisaggConfig(
                ServingMode(f"dsd-{old}-{d}", "dsd", new_chip, old, spec_k=spec_k,
                            acceptance=acc(d), interconnect=interconnect),
                tcfg, get_config(d)))
    return out
