"""Carbon accounting for LLM serving (GreenLLM §2.3, Eq. 1-3).

Total carbon of a request = embodied (amortized over hardware lifetime)
+ operational (energy x grid carbon intensity):

    C_req = (t_req / LT) * C_e  +  E_req * CI          (Eq. 3)

The chip database carries both the paper's GPU triple (A100/V100/T4,
Table 1) and the TPU-generation mapping this repo targets (v5e as the
"new" chip, v3/v2 as the "old" chips). All numbers are per-chip and
config-overridable; see DESIGN.md §2 for the adaptation rationale.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Static description of one accelerator generation."""

    name: str
    role: str                 # "new" | "old"
    peak_flops: float         # peak dense FLOP/s at serving dtype (bf16/fp16)
    hbm_bandwidth: float      # bytes/s
    hbm_capacity: float       # bytes
    max_power_w: float        # TDP, watts
    idle_power_w: float       # watts when powered but idle
    embodied_kg: float        # embodied carbon, kgCO2eq per chip
    year: int
    lifetime_years: float = 7.0
    # Interconnect attach rate for disaggregated transfer between pools.
    # Paper: 16 Gbps default GCP network; TPU DCN-class. Per-chip value.
    dcn_gbps: float = 16.0

    @property
    def embodied_g(self) -> float:
        return self.embodied_kg * 1000.0

    def embodied_rate_g_per_s(self, lifetime_years: float | None = None) -> float:
        """gCO2eq per second of amortized embodied carbon (Eq. 1 rate)."""
        lt = (lifetime_years if lifetime_years is not None else self.lifetime_years)
        return self.embodied_g / (lt * SECONDS_PER_YEAR)


# ---------------------------------------------------------------------------
# Chip database.
#
# GPU rows: the paper's Table 1 verbatim (fp16 TFLOPs, GB/s, W, kgCO2).
# NOTE the paper's Table 1 lists V100 FP16 at 28.26 TFLOPs (tensor-core
# FP16 is 112 TFLOPs; the table appears to use non-tensor FP16 FMA rate x2).
# We keep the paper's value for fidelity of the reproduction benchmarks and
# expose overrides for sensitivity studies.
#
# TPU rows: the generation mapping used for the TPU-native system. Embodied
# numbers follow the same ACT-style area+memory magnitudes as the paper's
# GPUs of comparable node/area (see DESIGN.md §2).
# ---------------------------------------------------------------------------
CHIP_DB: Mapping[str, ChipSpec] = {
    # --- paper Table 1 ---
    "a100": ChipSpec("a100", "new", 312e12, 1555e9, 40e9, 400.0, 60.0, 26.34, 2020),
    "v100": ChipSpec("v100", "old", 28.26e12, 900e9, 16e9, 300.0, 40.0, 20.0, 2017),
    "t4": ChipSpec("t4", "old", 65e12, 320e9, 16e9, 70.0, 17.0, 10.3, 2018),
    # --- TPU generation mapping (this repo's target) ---
    "tpu_v5e": ChipSpec("tpu_v5e", "new", 197e12, 819e9, 16e9, 250.0, 55.0, 26.3, 2023),
    "tpu_v3": ChipSpec("tpu_v3", "old", 123e12, 900e9, 32e9, 280.0, 55.0, 20.0, 2018),
    "tpu_v2": ChipSpec("tpu_v2", "old", 46e12, 700e9, 16e9, 200.0, 45.0, 10.3, 2017),
}

# Grid carbon intensities, gCO2eq/kWh (paper §7.5: NCSW/CISO/MISO).
GRID_CI: Mapping[str, float] = {
    "ncsw": 17.0,    # North Central Sweden (low)
    "ciso": 261.0,   # California ISO (medium; paper default)
    "miso": 501.0,   # Midcontinent ISO (high)
}
DEFAULT_CI = GRID_CI["ciso"]

J_PER_KWH = 3.6e6


def operational_carbon_g(energy_j: float, ci_g_per_kwh: float = DEFAULT_CI) -> float:
    """Eq. 2: operational carbon (g) of a request consuming `energy_j` joules."""
    if energy_j < 0:
        raise ValueError(f"negative energy: {energy_j}")
    return energy_j / J_PER_KWH * ci_g_per_kwh


def embodied_carbon_g(
    time_s: float,
    chip: ChipSpec,
    num_chips: int = 1,
    lifetime_years: float | None = None,
) -> float:
    """Eq. 1: embodied carbon (g) amortized over `time_s` of chip occupancy."""
    if time_s < 0:
        raise ValueError(f"negative time: {time_s}")
    return time_s * chip.embodied_rate_g_per_s(lifetime_years) * num_chips


def total_carbon_g(
    time_s: float,
    energy_j: float,
    chip: ChipSpec,
    ci_g_per_kwh: float = DEFAULT_CI,
    num_chips: int = 1,
    lifetime_years: float | None = None,
) -> float:
    """Eq. 3: total = embodied + operational carbon of a request."""
    return embodied_carbon_g(time_s, chip, num_chips, lifetime_years) + operational_carbon_g(
        energy_j, ci_g_per_kwh
    )


@dataclasses.dataclass(frozen=True)
class CarbonBreakdown:
    """Carbon of one execution (request / window), split by source."""

    operational_g: float
    embodied_g: float

    @property
    def total_g(self) -> float:
        return self.operational_g + self.embodied_g

    def __add__(self, other: "CarbonBreakdown") -> "CarbonBreakdown":
        return CarbonBreakdown(
            self.operational_g + other.operational_g,
            self.embodied_g + other.embodied_g,
        )

    def scale(self, k: float) -> "CarbonBreakdown":
        return CarbonBreakdown(self.operational_g * k, self.embodied_g * k)

    @staticmethod
    def zero() -> "CarbonBreakdown":
        return CarbonBreakdown(0.0, 0.0)


def request_carbon(
    busy_time_s: float,
    energy_j: float,
    chip: ChipSpec,
    *,
    ci_g_per_kwh: float = DEFAULT_CI,
    num_chips: int = 1,
    lifetime_years: float | None = None,
) -> CarbonBreakdown:
    """Carbon breakdown for a request occupying `num_chips` of `chip`."""
    return CarbonBreakdown(
        operational_g=operational_carbon_g(energy_j, ci_g_per_kwh),
        embodied_g=embodied_carbon_g(busy_time_s, chip, num_chips, lifetime_years),
    )


def savings_fraction(baseline: CarbonBreakdown, candidate: CarbonBreakdown) -> float:
    """Fractional total-carbon savings of `candidate` vs `baseline` (paper Fig. 9)."""
    if baseline.total_g <= 0:
        return 0.0
    return 1.0 - candidate.total_g / baseline.total_g


# ---------------------------------------------------------------------------
# Time-varying grid carbon intensity.
#
# The paper (§7.5) evaluates at three *static* regional intensities; real
# grids swing by 2-3x over a day (solar duck curve). `CarbonTrace` is a
# piecewise-constant CI signal that `SimResult.account()` integrates the
# simulated energy timeline against, so provisioning decisions (the fleet
# allocator) and sweeps (benchmarks/fleet_sweep.py) can be carbon-aware in
# time, not just in region. A flat trace reproduces scalar-CI accounting
# exactly (tests/test_fleet.py pins this).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CarbonTrace:
    """Piecewise-constant gCO2eq/kWh over time.

    `times_s[i]` is the start of segment i; segment i holds `ci[i]` until
    `times_s[i+1]` (the last value extends to +inf, and `ci[0]` extends
    back to -inf so pre-window energy is still priced). Times must be
    strictly increasing and start at 0.
    """

    times_s: tuple[float, ...]
    ci: tuple[float, ...]

    def __post_init__(self):
        if len(self.times_s) != len(self.ci) or not self.times_s:
            raise ValueError("times_s and ci must be same non-zero length")
        if any(b <= a for a, b in zip(self.times_s, self.times_s[1:])):
            raise ValueError("times_s must be strictly increasing")
        if any(v < 0 for v in self.ci):
            raise ValueError("carbon intensity must be non-negative")

    # ---------------------------------------------------------- constructors
    @staticmethod
    def flat(ci_g_per_kwh: float = DEFAULT_CI) -> "CarbonTrace":
        return CarbonTrace((0.0,), (float(ci_g_per_kwh),))

    @staticmethod
    def step(period_s: float, low: float, high: float,
             start_low: bool = True, horizon_s: float | None = None) -> "CarbonTrace":
        """Square wave alternating `low`/`high` every `period_s` seconds."""
        if period_s <= 0:
            raise ValueError("period_s must be positive")
        horizon = horizon_s if horizon_s is not None else 24 * period_s
        times, vals = [], []
        t, lo = 0.0, start_low
        while t < horizon:
            times.append(t)
            vals.append(low if lo else high)
            t += period_s
            lo = not lo
        return CarbonTrace(tuple(times), tuple(vals))

    @staticmethod
    def sinusoid(mean: float, amplitude: float, period_s: float,
                 steps_per_period: int = 24, horizon_s: float | None = None,
                 phase: float = 0.0) -> "CarbonTrace":
        """Diurnal-style swing, sampled into `steps_per_period` flat steps."""
        import math as _math

        if amplitude > mean:
            raise ValueError("amplitude > mean would give negative CI")
        horizon = horizon_s if horizon_s is not None else period_s
        dt = period_s / steps_per_period
        times, vals = [], []
        t = 0.0
        while t < horizon:
            mid = t + dt / 2
            times.append(t)
            vals.append(mean + amplitude * _math.sin(2 * _math.pi * mid / period_s + phase))
            t += dt
        return CarbonTrace(tuple(times), tuple(vals))

    @staticmethod
    def from_csv(path: str) -> "CarbonTrace":
        """Load `t_seconds,ci_g_per_kwh` rows (header optional, '#' comments).

        Row order does not matter (real exports are often tail-appended or
        region-interleaved): rows are sorted by timestamp, and rows with
        an exactly duplicated timestamp collapse to the LAST occurrence
        (the usual convention for corrected re-publishes of a grid
        boundary). A single-row file is a flat trace."""
        times, vals = [], []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                a, b = line.split(",")[:2]
                try:
                    times.append(float(a))
                except ValueError:
                    continue              # header row
                vals.append(float(b))
        by_time = {}                      # last value per timestamp wins
        for t, v in zip(times, vals):
            by_time[t] = v
        if not by_time:
            raise ValueError(f"no data rows in trace CSV: {path}")
        ts = sorted(by_time)
        return CarbonTrace(tuple(ts), tuple(by_time[t] for t in ts))

    def scaled(self, time_scale: float) -> "CarbonTrace":
        """Compress/stretch the time axis by `time_scale` (CI values keep
        their shape): a 24 h daily CSV replayed over a 600 s simulation is
        `trace.scaled(600 / 86400)`."""
        if time_scale <= 0:
            raise ValueError(f"time_scale must be positive: {time_scale}")
        return CarbonTrace(tuple(t * time_scale for t in self.times_s), self.ci)

    # ---------------------------------------------------------- evaluation
    def ci_at(self, t_s: float) -> float:
        import bisect

        i = bisect.bisect_right(self.times_s, t_s) - 1
        return self.ci[max(i, 0)]

    def mean_ci(self, t0_s: float, t1_s: float) -> float:
        """Time-average CI over [t0, t1] (== ci_at(t0) for zero-width)."""
        import bisect

        if t1_s < t0_s:
            raise ValueError(f"inverted interval [{t0_s}, {t1_s}]")
        if t1_s == t0_s:
            return self.ci_at(t0_s)
        # only segments overlapping [t0, t1] contribute; account() calls
        # this once per charged step, so bound the scan to that window
        first = max(bisect.bisect_right(self.times_s, t0_s) - 1, 0)
        last = max(bisect.bisect_right(self.times_s, t1_s) - 1, 0)
        total = 0.0
        for i in range(first, last + 1):
            start = float("-inf") if i == 0 else self.times_s[i]
            end = self.times_s[i + 1] if i + 1 < len(self.times_s) else float("inf")
            lo, hi = max(start, t0_s), min(end, t1_s)
            if hi > lo:
                total += self.ci[i] * (hi - lo)
        return total / (t1_s - t0_s)

    def operational_g(self, energy_j: float, t0_s: float, t1_s: float) -> float:
        """Eq. 2 with time-varying CI: energy spread uniformly over [t0, t1]."""
        return operational_carbon_g(energy_j, self.mean_ci(t0_s, t1_s))


def resolve_ci(ci: "float | CarbonTrace", t0_s: float, t1_s: float) -> float:
    """Scalar CI for energy spent uniformly over [t0, t1]."""
    if isinstance(ci, CarbonTrace):
        return ci.mean_ci(t0_s, t1_s)
    return float(ci)
