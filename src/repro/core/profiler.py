"""Profiler: the paper's component ② (§4.2).

Sweeps every serving configuration over (application x request size x QPS)
and records latency, energy, and carbon into the two matrices the
SLO-aware scheduler consumes: C (carbon per token) and SLO_att (SLO
attainment), both indexed [configuration, workload].

On this CPU-only container the measurement backend is the cluster
simulator (whose per-iteration timing model the real-compute engine
validates, including measured speculative-acceptance rates); on real
hardware the same `Profiler` interface is backed by device telemetry
(pynvml in the paper; TPU power telemetry here).

Entries can be deliberately subsampled (`coverage < 1`) to exercise the
collaborative-filtering completion exactly as the paper describes (Fig. 8:
shaded = profiled, blank = filled by CF).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.carbon import DEFAULT_CI
from repro.core.disagg import DisaggConfig
from repro.serving.simulator import simulate
from repro.serving.workload import DATASETS, Dataset, sample_requests


@dataclasses.dataclass(frozen=True)
class WorkloadPoint:
    """One row of the paper's matrices: an application at a QPS level."""

    dataset: str
    percentile: str          # request-size bucket: p25 | p50 | p75
    qps: float

    @property
    def key(self) -> str:
        return f"{self.dataset}/{self.percentile}@{self.qps:g}"


@dataclasses.dataclass
class ProfileEntry:
    carbon_per_token_g: float
    slo_attainment: float
    mean_ttft_s: float
    mean_tpot_s: float
    energy_j: float
    tokens: int


@dataclasses.dataclass
class ProfileDB:
    configs: list[str]
    workloads: list[str]
    entries: dict[tuple[str, str], ProfileEntry]

    def matrices(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (C, SLO_att, observed-mask), shape [config, workload]."""
        nc, nw = len(self.configs), len(self.workloads)
        c = np.full((nc, nw), np.nan)
        s = np.full((nc, nw), np.nan)
        for (ci, wi), e in self.entries.items():
            i, j = self.configs.index(ci), self.workloads.index(wi)
            c[i, j] = e.carbon_per_token_g
            s[i, j] = e.slo_attainment
        mask = ~np.isnan(c)
        return c, s, mask


def profile(
    catalog: Sequence[DisaggConfig],
    workloads: Sequence[WorkloadPoint],
    duration_s: float = 90.0,
    ci: float = DEFAULT_CI,
    seed: int = 0,
    coverage: float = 1.0,
) -> ProfileDB:
    """Run the sweep. `coverage < 1` leaves a random subset unmeasured."""
    rng = np.random.default_rng(seed)
    entries: dict[tuple[str, str], ProfileEntry] = {}
    for w in workloads:
        ds = DATASETS[w.dataset]
        reqs = sample_requests(ds, w.qps, duration_s, seed=seed,
                               fixed_size=ds.size_at(w.percentile))
        for cfg in catalog:
            if coverage < 1.0 and rng.random() > coverage:
                continue
            res = simulate(cfg.mode, cfg.target, reqs, draft_cfg=cfg.draft, seed=seed)
            entries[(cfg.name, w.key)] = ProfileEntry(
                carbon_per_token_g=res.carbon_per_token(ci),
                slo_attainment=res.slo_attainment(ds),
                mean_ttft_s=res.mean_ttft(),
                mean_tpot_s=res.mean_tpot(),
                energy_j=sum(u.energy_j for u in res.use.values()),
                tokens=res.total_tokens,
            )
    return ProfileDB([c.name for c in catalog], [w.key for w in workloads], entries)
