"""Speculative decoding (Leviathan et al.) in pure JAX - the compute core
of the paper's Disg-Spec-Decode optimizer (§2.2, §4.1).

One *round*:
  1. the draft model autoregressively proposes K tokens (K serve_steps,
     plus one bookkeeping step so its cache stays consistent when all K
     are accepted),
  2. the target model scores [last, d_1..d_K] in ONE extend_step pass,
  3. the verifier accepts d_i with probability min(1, q_i/p_i) (exact
     rejection sampling), resamples the first rejected position from the
     residual max(q - p, 0), or samples a bonus token when all K are
     accepted.

Everything is batched; acceptance lengths vary per sequence and cache
rollback is per-sequence via the (B,) `pos` vector (stale KV above `pos`
is masked and later overwritten). The distribution of emitted tokens
provably equals the target model's (test_spec_decode.py checks this
property empirically).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import backbone
from repro.models.config import ModelConfig
from repro.models.layers import DEFAULT_EXEC, ExecConfig


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    num_draft_tokens: int = 4     # K
    temperature: float = 1.0


def _sample(rng: jax.Array, probs: jax.Array) -> jax.Array:
    """Categorical sample from a (B, V) probability matrix."""
    logits = jnp.log(jnp.maximum(probs, 1e-30))
    return jax.random.categorical(rng, logits, axis=-1)


def _probs(logits: jax.Array, temperature: float) -> jax.Array:
    return jax.nn.softmax(logits.astype(jnp.float32) / max(temperature, 1e-4), axis=-1)


def draft_propose(
    params, cache, last_tokens: jax.Array, cfg: ModelConfig, spec: SpecConfig,
    rng: jax.Array, exec_cfg: ExecConfig = DEFAULT_EXEC,
):
    """Propose K draft tokens. Returns (tokens (B,K), probs (B,K,V), cache).

    Runs K+1 serve_steps: [last, d_1..d_K]. The final step only advances the
    draft cache so that the all-accepted case leaves it consistent."""
    k = spec.num_draft_tokens
    tokens, probs = [], []
    cur = last_tokens
    for i in range(k):
        logits, cache = backbone.serve_step(params, cache, cur, cfg, exec_cfg)
        p = _probs(logits, spec.temperature)
        rng, sub = jax.random.split(rng)
        cur = _sample(sub, p)
        tokens.append(cur)
        probs.append(p)
    _, cache = backbone.serve_step(params, cache, cur, cfg, exec_cfg)  # bookkeeping
    return jnp.stack(tokens, axis=1), jnp.stack(probs, axis=1), cache


def verify(
    rng: jax.Array,
    target_logits: jax.Array,    # (B, K+1, V): dists after [last, d_1..d_K]
    draft_probs: jax.Array,      # (B, K, V)
    draft_tokens: jax.Array,     # (B, K)
    temperature: float = 1.0,
):
    """Exact rejection-sampling verification.

    Returns (out_tokens (B, K+1), n_emitted (B,), n_accepted (B,)).
    out_tokens[:, :n_emitted] are committed; entries beyond are zeros."""
    b, k = draft_tokens.shape
    q = _probs(target_logits, temperature)               # (B, K+1, V)
    q_at = jnp.take_along_axis(q[:, :k], draft_tokens[..., None], axis=-1)[..., 0]
    p_at = jnp.take_along_axis(draft_probs, draft_tokens[..., None], axis=-1)[..., 0]
    rng_u, rng_res = jax.random.split(rng)
    u = jax.random.uniform(rng_u, (b, k))
    accept = u < jnp.minimum(1.0, q_at / jnp.maximum(p_at, 1e-30))
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)  # (B,)

    # distribution for the extra token: residual at the rejection position,
    # or the target's bonus distribution when everything was accepted
    q_n = jnp.take_along_axis(q, n_acc[:, None, None], axis=1)[:, 0]        # (B, V)
    p_n = jnp.take_along_axis(
        draft_probs, jnp.minimum(n_acc, k - 1)[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(q_n - p_n, 0.0)
    res_sum = residual.sum(-1, keepdims=True)
    residual = jnp.where(res_sum > 1e-30, residual / jnp.maximum(res_sum, 1e-30), q_n)
    extra_dist = jnp.where((n_acc == k)[:, None], q_n, residual)
    extra = _sample(rng_res, extra_dist)                                    # (B,)

    idx = jnp.arange(k + 1)[None, :]
    padded = jnp.concatenate([draft_tokens, jnp.zeros((b, 1), draft_tokens.dtype)], axis=1)
    out = jnp.where(idx < n_acc[:, None], padded, 0)
    out = jnp.where(idx == n_acc[:, None], extra[:, None], out)
    return out, n_acc + 1, n_acc


def spec_decode_round(
    target_params, target_cfg: ModelConfig, target_cache,
    draft_params, draft_cfg: ModelConfig, draft_cache,
    last_tokens: jax.Array, spec: SpecConfig, rng: jax.Array,
    exec_cfg: ExecConfig = DEFAULT_EXEC,
):
    """One full draft -> transfer -> verify cycle.

    Returns dict with committed tokens, per-sequence counts, updated caches
    and the inter-pool payload sizes (token ids vs draft probs) that the
    disaggregation layer prices against the interconnect (paper Fig. 7).
    """
    for c, which in ((target_cfg, "target"), (draft_cfg, "draft")):
        if c.family in ("ssm", "hybrid"):
            # extend_step verification works for recurrent families, but
            # per-sequence rollback would need per-step state checkpoints;
            # the serving layer routes these archs to standard decode
            # (DESIGN.md §4 Arch-applicability).
            raise NotImplementedError(
                f"spec-decode {which} model {c.name} is recurrent ({c.family}); "
                "per-sequence state rollback is not supported"
            )
    k = spec.num_draft_tokens
    rng_d, rng_v = jax.random.split(rng)
    t_pos0 = target_cache["pos"]
    d_pos0 = draft_cache["pos"]

    d_tokens, d_probs, draft_cache = draft_propose(
        draft_params, draft_cache, last_tokens, draft_cfg, spec, rng_d, exec_cfg)

    target_in = jnp.concatenate([last_tokens[:, None], d_tokens], axis=1)  # (B, K+1)
    t_logits, target_cache = backbone.extend_step(
        target_params, target_cache, target_in, target_cfg, exec_cfg)

    out, n_emitted, n_acc = verify(rng_v, t_logits, d_probs, d_tokens, spec.temperature)

    # per-sequence rollback: keep prefix + last + accepted drafts processed
    target_cache = dict(target_cache, pos=t_pos0 + 1 + n_acc)
    draft_cache = dict(draft_cache, pos=d_pos0 + 1 + n_acc)

    new_last = jnp.take_along_axis(out, n_acc[:, None], axis=1)[:, 0]
    b = last_tokens.shape[0]
    return {
        "tokens": out,
        "n_emitted": n_emitted,
        "n_accepted": n_acc,
        "new_last": new_last,
        "target_cache": target_cache,
        "draft_cache": draft_cache,
        # bytes crossing the pool boundary per round (Fig. 4 / Fig. 7);
        # draft probs ship fp16 (the verifier's acceptance test tolerates it)
        "bytes_token_ids": b * k * 4,
        "bytes_draft_probs": b * k * draft_cfg.vocab_size * 2,
    }


def expected_tokens_per_round(alpha: float, k: int) -> float:
    """E[#emitted tokens] for per-token acceptance rate alpha (analytic)."""
    if abs(1.0 - alpha) < 1e-9:
        return float(k + 1)
    return float((1.0 - alpha ** (k + 1)) / (1.0 - alpha))
