"""Mélange-style min-carbon fleet allocator (provisioning-time decisions).

GreenLLM's scheduler answers "which configuration serves this workload";
this module answers the fleet question above it: "how many instances of
each (chip, mode) do we provision, and which request sizes go where".
It follows Mélange's formulation (litone01/melange-release) with carbon as
the objective instead of dollars, and EcoServe-style provisioning-time
accounting: a provisioned instance pays its embodied amortization + idle
power for the whole window whether or not it is busy, so the optimizer is
rewarded for packing load onto few, well-utilized, low-carbon instances.

Inputs mirror Mélange's contract:

  workload_distribution  - 2D matrix over (prompt-bucket, output-bucket),
                           cell = fraction of traffic in that size range
                           (rows prompt, cols output; sums to 1)
  gpu_info               - per instance type: max sustained QPS per bucket
                           under the dataset's TTFT/TPOT SLOs (`tputs`,
                           0 = SLO-infeasible), fixed carbon g/hour when
                           provisioned, dynamic carbon g/request per bucket
  total_request_rate     - overall arrival rate (QPS)

`build_gpu_info` derives `gpu_info` analytically from the same perfmodel
rooflines the cluster simulator charges, so allocations validated here
hold up when replayed through `serving.fleet.simulate_fleet`. The solver
is greedy first-fit-decreasing over load slices plus a close/swap local
search - no external ILP dependency, deterministic for fixed inputs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Optional, Sequence

from repro.core.carbon import (
    CHIP_DB,
    DEFAULT_CI,
    J_PER_KWH,
    CarbonTrace,
    resolve_ci,
)
from repro.core.disagg import DisaggConfig
from repro.core.spec_decode import expected_tokens_per_round
from repro.serving.batching import (
    BatchPolicy,
    build_dpd_decode_ledger,
    build_single_pool_scheduler,
    prompt_chunks,
    resolve_batch_policy,
)
from repro.serving.costs import (
    dpd_kv_bytes,
    dsd_link_bytes,
    prefill_charges,
    shared_pricer,
    spec_round_charges,
    spec_round_time,
)
from repro.serving.fleet import FLEET_BATCHING_DEFAULT, SizeBuckets
from repro.serving.perfmodel import decode_cost, max_concurrency
from repro.serving.workload import SLO_CLASSES, Dataset, Request, slo_targets

Matrix = tuple[tuple[float, ...], ...]


# ---------------------------------------------------------------------------
# Workload bucketing
# ---------------------------------------------------------------------------
def bucket_workload(requests: Sequence[Request],
                    buckets: SizeBuckets) -> Matrix:
    """Empirical `workload_distribution`: per-bucket traffic fractions."""
    np_, no = buckets.shape
    counts = [[0] * no for _ in range(np_)]
    for r in requests:
        i, j = buckets.index(r.prompt_len, r.output_len)
        counts[i][j] += 1
    n = max(len(requests), 1)
    return tuple(tuple(c / n for c in row) for row in counts)


# ---------------------------------------------------------------------------
# Per-instance-type profile (the gpu_info entry)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InstanceProfile:
    """One Mélange `gpu_info` row, in carbon units."""

    name: str
    tputs: Matrix                    # max sustained QPS per bucket (0 = infeasible)
    carbon_fixed_g_per_hour: float   # embodied amortization + idle power, provisioned
    carbon_per_request_g: Matrix     # dynamic (busy energy) carbon per request
    # physical chips one instance of this type occupies (dpd/dsd use two);
    # empty = exempt from `allocate(inventory=...)` availability limits
    chips: tuple[str, ...] = ()

    def feasible_anywhere(self) -> bool:
        return any(t > 0 for row in self.tputs for t in row)


def _engine_profile(cfg: DisaggConfig, pl: int, ol: int,
                    ds: Dataset, utilization: float):
    """(qps_max, energy_per_request_j, busy_s_per_request_by_chip) of one
    instance on fixed-size load, or (0, inf, {}) when the bucket cannot
    meet the dataset's SLOs.

    Mirrors the simulator's serialized engine: prefills preempt decode, so
    a request's service demand is its prefill time plus its share of the
    decode rounds; `utilization` head-room absorbs Poisson queueing (tail
    TTFT under bursts - do not run interactive engines near 1.0).

    Energy is evaluated at the *operating* batch, not the largest
    SLO-feasible batch: a Little's-law fixed point of `active sequences =
    arrival rate x decode residence` at the utilization target. At low
    target rates engines run small batches where weight reads do not
    amortize - exactly the regime where GreenLLM's old-chip speculative
    modes save energy - and allocating off max-batch numbers would hide
    that."""
    mode = cfg.mode
    new_chip = CHIP_DB[mode.new_chip]
    old_chip = CHIP_DB[mode.old_chip] if mode.old_chip else None
    ctx = pl + ol
    decode_chip = old_chip if mode.kind == "dpd" else new_chip
    cap = min(mode.max_batch, max_concurrency(cfg.target, decode_chip, ctx))
    if mode.kind == "spec":
        cap = min(cap, max_concurrency(cfg.draft, new_chip, ctx))
    if cap < 1:
        return 0.0, math.inf, {}

    # prefill admission: the shared cost schedule (serving/costs.py), so
    # allocator throughputs price exactly what the simulator/engine charge
    sched = prefill_charges(mode.kind, cfg.target, cfg.draft,
                            new_chip, old_chip, pl)
    ttft = sched.duration_s
    pre_energy = sum(c.energy_j for _, c, _ in sched.charges)
    pre_busy: dict[str, float] = {}
    for chip_name, c, _ in sched.charges:
        pre_busy[chip_name] = pre_busy.get(chip_name, 0.0) + c.time_s
    if ttft > ds.ttft_slo_s:
        return 0.0, math.inf, {}

    def round_cost(b: int):
        """(round s, tokens/req/round, round J, busy s by chip) at batch b."""
        if mode.kind in ("standalone", "dpd"):
            c = decode_cost(cfg.target, decode_chip, b, ctx)
            return c.time_s, 1.0, c.energy_j, {decode_chip.name: c.time_s}
        k = mode.spec_k
        draft_chip, c_d, c_t = spec_round_charges(
            mode.kind, cfg.target, cfg.draft, new_chip, old_chip, b, ctx, k)
        busy = {draft_chip.name: c_d.time_s}
        busy[new_chip.name] = busy.get(new_chip.name, 0.0) + c_t.time_s
        if mode.kind == "spec":
            t_round = spec_round_time(mode.kind, c_d, c_t,
                                      mode.interconnect, 0, 0)
        else:
            ids_b, probs_b = dsd_link_bytes(cfg.draft, b, k)
            t_round = spec_round_time(mode.kind, c_d, c_t, mode.interconnect,
                                      ids_b, probs_b,
                                      overlap=mode.overlap_comm)
        return t_round, expected_tokens_per_round(mode.acceptance, k), \
            c_d.energy_j + c_t.energy_j, busy

    def feasible_at(b: int) -> bool:
        t_round, e_tok, _, _ = round_cost(b)
        return t_round / e_tok <= ds.tpot_slo_s

    if not feasible_at(1):
        return 0.0, math.inf, {}
    b_slo = max(b for b in sorted({1, 2, 4, 8, 16, 32, cap})
                if b <= cap and feasible_at(b))

    def rounds_per_req_at(b: int) -> float:
        _, e_tok, _, _ = round_cost(b)
        return max(ol - 1, 0) / e_tok

    def lambda_max_at(b: int) -> float:
        """Arrival rate a continuous-batching engine sustains at batch b:
        Little's law with the prefill time share carved out -
        b = lam * rounds * t_round / (1 - lam * p)  =>
        lam = b / (rounds * t_round + b * p)."""
        t_round, _, _, _ = round_cost(b)
        denom = rounds_per_req_at(b) * t_round + b * ttft
        if mode.kind == "dpd":
            # pools run concurrently; the binding resource is the slowest
            # of prefill pool, decode pool, and the KV link
            kv_bytes = dpd_kv_bytes(cfg.target, pl)
            return min(1.0 / max(ttft, 1e-12),
                       b / max(rounds_per_req_at(b) * t_round, 1e-12),
                       1.0 / max(mode.interconnect.transfer_time(kv_bytes), 1e-12))
        return b / max(denom, 1e-12)

    qps = utilization * lambda_max_at(b_slo)

    # operating batch at that rate: b = qps * rounds * t_round(b) / (1 - qps*p)
    b_op = b_slo
    phi = min(qps * ttft, 0.9) if mode.kind != "dpd" else 0.0
    for _ in range(8):
        t_round, _, _, _ = round_cost(b_op)
        b_next = min(max(int(round(
            qps * rounds_per_req_at(b_op) * t_round / (1.0 - phi))), 1), b_slo)
        if b_next == b_op:
            break
        b_op = b_next

    t_round, e_tok, en_round, busy_round = round_cost(b_op)
    rounds_per_req = max(ol - 1, 0) / e_tok
    energy = pre_energy + rounds_per_req * en_round / b_op
    busy = dict(pre_busy)
    for chip_name, t in busy_round.items():
        busy[chip_name] = busy.get(chip_name, 0.0) + rounds_per_req * t / b_op
    return qps, energy, busy


def _hs_stats(hs) -> tuple[float, dict[str, float]]:
    """(total energy J, busy seconds by chip) of one `HybridSchedule`."""
    en = sum(c.energy_j for _, c, _ in hs.charges)
    busy: dict[str, float] = {}
    for name, c, _ in hs.charges:
        busy[name] = busy.get(name, 0.0) + c.time_s
    return en, busy


def _engine_profile_continuous(cfg: DisaggConfig, pl: int, ol: int,
                               ds: Dataset, utilization: float,
                               policy: BatchPolicy):
    """`_engine_profile` for the iteration-level continuous executor.

    Mirrors what `ReplicaSim(batching="continuous")` actually serves:
    admission is block-granular (the SAME ledger sizing the executors
    build via batching.py), prefill is chunked and batched - riding
    inside hybrid decode steps for standalone, dedicated budget-bounded
    steps for spec/dsd and the dpd prefill pool - and every step is
    priced through `costs.shared_pricer`'s keyed memo (the entries the
    executors populate). The serialized profile's
    `b * ttft` stop-the-world term disappears from the standalone
    denominator (prefill no longer steals whole iterations), which is
    exactly the capacity the continuous executor recovers; spec/dsd/dpd
    keep the term but amortize it over the prompts one prefill step
    batches."""
    mode = cfg.mode
    new_chip = CHIP_DB[mode.new_chip]
    old_chip = CHIP_DB[mode.old_chip] if mode.old_chip else None
    ctx = pl + ol
    k = mode.spec_k

    # block-granular admission cap (full-lifetime context per sequence)
    if mode.kind == "dpd":
        num_blocks = build_dpd_decode_ledger(
            policy, cfg.target, old_chip).num_blocks
    else:
        num_blocks = build_single_pool_scheduler(
            policy, mode.kind, mode.max_batch, k, cfg.target, cfg.draft,
            new_chip).ledger.num_blocks
    per_seq = -(-ctx // policy.block_size)
    cap = min(mode.max_batch,
              num_blocks // per_seq if per_seq else mode.max_batch)
    if cap < 1:
        return 0.0, math.inf, {}

    # the SAME memo entries the executors populate: profile grids for a
    # configuration the fleet already simulated are pure cache hits
    if mode.kind == "dpd":
        pricer = shared_pricer("dpd", cfg.target, None, new_chip, old_chip,
                               interconnect=mode.interconnect)
    else:
        pricer = shared_pricer(mode.kind, cfg.target, cfg.draft, new_chip,
                               old_chip, k=k, interconnect=mode.interconnect,
                               overlap=mode.overlap_comm)

    def hs_of(chunk_specs, b):
        return pricer.charges(tuple(chunk_specs), (ctx,) * b)

    chunks = prompt_chunks(pl, policy.chunk_tokens)
    grid = sorted({1, 2, 4, 8, 16, 32, cap})

    if mode.kind == "standalone":
        def round_at(b):
            """Steady-state hybrid step: b decode slots + their prefill
            feed (each resident request contributes pl tokens over its
            ol-1 rounds), clipped to the step token budget."""
            need = b * pl / max(ol - 1, 1)
            avail = max(policy.token_budget - b, 0)
            c_tok = int(round(min(need, avail)))
            specs = ((c_tok, pl // 2),) if c_tok >= 1 else ()
            return hs_of(specs, b)

        def feasible_at(b):
            if round_at(b).duration_s > ds.tpot_slo_s:
                return False
            ttft = sum(hs_of((c,), b).duration_s for c in chunks)
            return ttft <= ds.ttft_slo_s

        if ol <= 1:
            # pure-prefill bucket: budget-bounded chunk steps, amortized
            # over the m prompts one step batches
            m = max(policy.token_budget // max(pl, 1), 1)
            hs = hs_of(((pl, 0),) * m, 0) if m > 1 else None
            steps = [hs] if hs else [hs_of((c,), 0) for c in chunks]
            dur = sum(s.duration_s for s in steps) / m
            if sum(hs_of((c,), 0).duration_s for c in chunks) > ds.ttft_slo_s:
                return 0.0, math.inf, {}
            qps = utilization / max(dur, 1e-12)
            en = sum(_hs_stats(s)[0] for s in steps) / m
            busy: dict[str, float] = {}
            for s in steps:
                for cn, t in _hs_stats(s)[1].items():
                    busy[cn] = busy.get(cn, 0.0) + t / m
            return qps, en, busy
        if not feasible_at(1):
            return 0.0, math.inf, {}
        b_slo = max(b for b in grid if b <= cap and feasible_at(b))

        def lam_max(b):
            t = round_at(b).duration_s
            lam_dec = b / max((ol - 1) * t, 1e-12)
            lam_pre = max(policy.token_budget - b, 0) / max(pl * t, 1e-12)
            return min(lam_dec, lam_pre)

        qps = utilization * lam_max(b_slo)
        b_op = b_slo
        for _ in range(8):
            t = round_at(b_op).duration_s
            b_next = min(max(int(round(qps * (ol - 1) * t)), 1), b_slo)
            if b_next == b_op:
                break
            b_op = b_next
        en_round, busy_round = _hs_stats(round_at(b_op))
        # a request is 1 of b_op residents for ol-1 rounds, and its chunk
        # tokens are 1/b_op of the step's feed - both scale as 1/b_op
        rounds = max(ol - 1, 0)
        energy = rounds * en_round / b_op
        busy = {cn: rounds * t / b_op for cn, t in busy_round.items()}
        return qps, energy, busy

    # spec / dsd / dpd: dedicated budget-bounded prefill steps, amortized
    # over the m whole prompts one step batches (chunked when pl exceeds
    # the budget/chunk size)
    pre_chunk = policy.token_budget if mode.kind == "dpd" \
        else policy.chunk_tokens
    pre_split = prompt_chunks(pl, pre_chunk)
    m = max(policy.token_budget // max(pl, 1), 1)
    pre_steps = [hs_of(((pl, 0),) * m, 0)] if m > 1 \
        else [hs_of((c,), 0) for c in pre_split]
    pre_dur = sum(s.duration_s for s in pre_steps) / m
    pre_en = sum(_hs_stats(s)[0] for s in pre_steps) / m
    pre_busy: dict[str, float] = {}
    for s in pre_steps:
        for cn, t in _hs_stats(s)[1].items():
            pre_busy[cn] = pre_busy.get(cn, 0.0) + t / m
    ttft = sum(hs_of((c,), 0).duration_s for c in pre_split)
    if mode.kind == "dpd":
        ttft += mode.interconnect.transfer_time(dpd_kv_bytes(cfg.target, pl))
    if ttft > ds.ttft_slo_s:
        return 0.0, math.inf, {}

    e_tok = 1.0 if mode.kind == "dpd" \
        else expected_tokens_per_round(mode.acceptance, k)
    rounds_per_req = max(ol - 1, 0) / e_tok

    def feasible_at(b):
        return hs_of((), b).duration_s / e_tok <= ds.tpot_slo_s

    if not feasible_at(1):
        return 0.0, math.inf, {}
    b_slo = max(b for b in grid if b <= cap and feasible_at(b))

    def lam_max(b):
        t_round = hs_of((), b).duration_s
        if mode.kind == "dpd":
            # pools run concurrently; slowest of prefill pool, decode
            # pool, and the KV link binds
            kv_bytes = dpd_kv_bytes(cfg.target, pl)
            return min(1.0 / max(pre_dur, 1e-12),
                       b / max(rounds_per_req * t_round, 1e-12),
                       1.0 / max(mode.interconnect.transfer_time(kv_bytes),
                                 1e-12))
        return b / max(rounds_per_req * t_round + b * pre_dur, 1e-12)

    qps = utilization * lam_max(b_slo)
    b_op = b_slo
    phi = min(qps * pre_dur, 0.9) if mode.kind != "dpd" else 0.0
    for _ in range(8):
        t_round = hs_of((), b_op).duration_s
        b_next = min(max(int(round(
            qps * rounds_per_req * t_round / (1.0 - phi))), 1), b_slo)
        if b_next == b_op:
            break
        b_op = b_next
    en_round, busy_round = _hs_stats(hs_of((), b_op))
    energy = pre_en + rounds_per_req * en_round / b_op
    busy = dict(pre_busy)
    for cn, t in busy_round.items():
        busy[cn] = busy.get(cn, 0.0) + rounds_per_req * t / b_op
    return qps, energy, busy


def provisioned_carbon_g_per_hour(mode_chips: Sequence[str], ci: float,
                                  include_idle: bool = False) -> float:
    """Fixed hourly carbon of one provisioned instance.

    Default (EcoServe-style, matches the paper's Eq. 1 applied to the
    reservation window): chips reserved for this service amortize their
    embodied carbon over the reservation whether busy or not. With
    `include_idle`, reserved chips also draw idle power for the whole
    window - the strict beyond-paper accounting of `fig9 --strict`."""
    total = 0.0
    for name in mode_chips:
        chip = CHIP_DB[name]
        total += chip.embodied_rate_g_per_s() * 3600.0
        if include_idle:
            total += chip.idle_power_w * 3600.0 / J_PER_KWH * ci
    return total


def build_gpu_info(
    catalog: Sequence[DisaggConfig],
    dataset: Dataset,
    buckets: SizeBuckets,
    ci: "float | CarbonTrace" = DEFAULT_CI,
    utilization: Optional[float] = None,
    include_idle: bool = False,
    window_s: float = 3600.0,
    batching: "BatchPolicy | str | None" = None,
    slo_class: Optional[str] = None,
    calibration=None,
) -> dict[str, InstanceProfile]:
    """Profile every catalog config over the bucket grid (Mélange gpu_info).

    `utilization` is the per-instance load target: tputs are scaled so the
    solver leaves head-room for Poisson bursts and tail TTFT, and dynamic
    energy is evaluated at the operating batch that target implies. With a
    `CarbonTrace`, the window-average intensity prices the energy - the
    provisioning decision sees the same grid the fleet will run under.

    `batching` selects which executor the profiles model: the default is
    the fleet's iteration-level continuous policy (the real serving
    frontier - see `_engine_profile_continuous`); pass "serialized" to
    profile the legacy stop-the-world-prefill engines.

    `slo_class` gates per-bucket QPS on THAT latency class's TTFT/TPOT
    targets (workload.SLO_CLASSES scales of the dataset's base targets)
    instead of the dataset's single global pair, and - unless
    `utilization` is passed explicitly - provisions at the CLASS's load
    target (a relaxed class spends its TTFT slack on queueing and runs
    its instances hotter; tight keeps burst headroom). This is the
    per-class carbon headroom the priority scheduler then protects at
    serve time. None keeps the dataset targets and the 0.6 default
    (identical to the pre-class profiles).

    `include_idle` accounting: the fixed term charges idle power for the
    whole reservation window, but the roofline step energies the profiles
    sum ALSO include idle draw during busy seconds (P = idle + span*util).
    To avoid double-charging, each request's dynamic energy is credited
    idle_w x busy_s per chip - the profiles then rank by true
    above-idle (marginal) energy under strict accounting.

    `calibration` (a `perfmodel.Calibration`, artifact path, or True for
    the committed artifact) evaluates every profile under the measured
    roofline constants from `benchmarks/kernel_calibration.py` instead of
    the literature defaults."""
    if utilization is None:
        utilization = SLO_CLASSES[slo_class].utilization \
            if slo_class is not None else 0.6
    if not 0 < utilization <= 1:
        raise ValueError(f"utilization must be in (0, 1]: {utilization}")
    if slo_class is not None:
        ttft, tpot = slo_targets(dataset, slo_class)
        # NOTE: the class scaling is baked into the targets here, so the
        # replaced dataset keeps slo_class="standard" (scale 1.0) - also
        # tagging it with `slo_class` would double-encode the class for
        # any downstream slo_targets/slo_ok consumer
        dataset = dataclasses.replace(dataset, ttft_slo_s=ttft,
                                      tpot_slo_s=tpot)
    policy = resolve_batch_policy(batching, default=FLEET_BATCHING_DEFAULT)
    ci_val = resolve_ci(ci, 0.0, window_s)
    from repro.serving import perfmodel

    ctx = (perfmodel.calibrated(None if calibration is True else calibration)
           if calibration else contextlib.nullcontext())
    out: dict[str, InstanceProfile] = {}
    with ctx:
        for cfg in catalog:
            np_, no = buckets.shape
            tputs, dyn = [], []
            for i in range(np_):
                trow, drow = [], []
                for j in range(no):
                    pl, ol = buckets.rep_size(i, j)
                    if policy.kind == "continuous":
                        qps, energy_j, busy = _engine_profile_continuous(
                            cfg, pl, ol, dataset, utilization, policy)
                    else:
                        qps, energy_j, busy = _engine_profile(
                            cfg, pl, ol, dataset, utilization)
                    if include_idle and not math.isinf(energy_j):
                        # idle power during busy seconds is already charged
                        # by the whole-window fixed term; credit it so the
                        # dynamic term is the marginal (above-idle) energy
                        energy_j -= sum(
                            CHIP_DB[cn].idle_power_w * t
                            for cn, t in busy.items())
                        energy_j = max(energy_j, 0.0)
                    trow.append(qps)
                    drow.append(0.0 if math.isinf(energy_j)
                                else energy_j / J_PER_KWH * ci_val)
                tputs.append(tuple(trow))
                dyn.append(tuple(drow))
            out[cfg.name] = InstanceProfile(
                name=cfg.name,
                tputs=tuple(tputs),
                carbon_fixed_g_per_hour=provisioned_carbon_g_per_hour(
                    cfg.mode.chips(), ci_val, include_idle=include_idle),
                carbon_per_request_g=tuple(dyn),
                chips=tuple(cfg.mode.chips()),
            )
    return out


# ---------------------------------------------------------------------------
# The allocation problem
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Allocation:
    """Solver output: instance counts + size-aware routing fractions."""

    counts: dict[str, int]
    # bucket (i, j) -> {type name: requests/s routed there}
    assignment: dict[tuple[int, int], dict[str, float]]
    carbon_g_per_hour: float
    feasible: bool                  # False => some load had no SLO-feasible type
    utilization: dict[str, float]   # mean busy fraction per provisioned type
    # load (req/s) no provisioned-or-provisionable instance could serve at
    # all - only nonzero when `inventory` limits bind (feasible is False)
    unplaced_rate: float = 0.0
    # one-time boot carbon (g) of instances newly started vs `prev_counts`
    boot_g: float = 0.0
    # which backend produced this allocation: "greedy", "lp", or
    # "lp-fallback-greedy" (lp requested but scipy missing / solve failed)
    solver: str = "greedy"

    def total_instances(self) -> int:
        return sum(self.counts.values())

    def fleet_counts(self) -> dict[str, int]:
        return {k: v for k, v in self.counts.items() if v > 0}

    def raise_if_unserved(self) -> "Allocation":
        """Fail loudly when inventory limits left load with no instance."""
        if self.unplaced_rate > 0:
            raise ValueError(
                f"allocation infeasible: {self.unplaced_rate:.3g} req/s had "
                f"no instance within inventory limits (counts={self.counts})")
        return self


@dataclasses.dataclass
class _Slice:
    bucket: tuple[int, int]
    rate: float


@dataclasses.dataclass
class _Instance:
    type_name: str
    load: float = 0.0               # fraction of capacity consumed (<= 1)
    rates: dict[tuple[int, int], float] = dataclasses.field(default_factory=dict)

    def fits(self, frac: float) -> bool:
        return self.load + frac <= 1.0 + 1e-9

    def add(self, bucket: tuple[int, int], rate: float, frac: float) -> None:
        self.load += frac
        self.rates[bucket] = self.rates.get(bucket, 0.0) + rate


def _capacity_frac(info: InstanceProfile, bucket: tuple[int, int],
                   rate: float) -> float:
    t = info.tputs[bucket[0]][bucket[1]]
    return math.inf if t <= 0 else rate / t


def _dynamic_g_per_hour(info: InstanceProfile, bucket: tuple[int, int],
                        rate: float) -> float:
    return rate * 3600.0 * info.carbon_per_request_g[bucket[0]][bucket[1]]


def _allocate_lp(
    workload_distribution: Matrix,
    total_request_rate: float,
    gpu_info: dict[str, InstanceProfile],
    inventory: Optional[dict[str, int]] = None,
    prev_counts: Optional[dict[str, int]] = None,
    boot_carbon_g: float = 0.0,
    window_s: float = 3600.0,
    time_limit_s: float = 60.0,
) -> Optional[Allocation]:
    """Exact MILP formulation of the allocation problem (scipy `milp`).

    Variables: x_n (integer instance counts per type), r_{n,b} (req/s of
    bucket b routed to type n, only where tput_{n,b} > 0), y_n >= x_n -
    prev_n (booted instances, when boot carbon applies), u_b (unplaced
    slack, big-M penalized so the solver serves everything it can).
    Constraints: per-bucket rate conservation sum_n r_{n,b} + u_b =
    rate_b; per-type capacity sum_b r_{n,b}/tput_{n,b} <= x_n; physical
    chip inventory caps. Objective: fixed + dynamic + amortized boot
    carbon per hour - the same g/hour `allocate` reports, so greedy and
    LP solutions compare directly.

    Returns None when scipy's solver is unavailable or the solve fails /
    times out without an incumbent - the caller falls back to greedy.
    """
    try:
        import numpy as np
        from scipy.optimize import LinearConstraint, milp
        from scipy.optimize import Bounds
    except ImportError:
        return None
    mass = sum(c for row in workload_distribution for c in row)
    if mass <= 0:
        return Allocation({}, {}, 0.0, True, {}, solver="lp")
    names = sorted(gpu_info)
    prev = dict(prev_counts) if prev_counts else {}
    boot_g_per_hour = boot_carbon_g * 3600.0 / window_s

    rates: dict[tuple[int, int], float] = {}
    for i, row in enumerate(workload_distribution):
        for j, frac in enumerate(row):
            if frac > 0:
                rates[(i, j)] = frac / mass * total_request_rate
    bkts = sorted(rates)
    pairs = [(ni, bi) for ni, n in enumerate(names) for bi, b in enumerate(bkts)
             if gpu_info[n].tputs[b[0]][b[1]] > 0]

    N, B, P = len(names), len(bkts), len(pairs)
    use_boot = boot_g_per_hour > 0
    # layout: x (N ints) | r (P) | u (B) | y (N, only with boot carbon)
    nvar = N + P + B + (N if use_boot else 0)
    ix = lambda n: n                      # noqa: E731
    ir = lambda p: N + p                  # noqa: E731
    iu = lambda b: N + P + b              # noqa: E731
    iy = lambda n: N + P + B + n          # noqa: E731

    # big-M on unplaced load: dominate the cost of serving one req/s on
    # the most expensive type by a wide margin
    worst = max((info.carbon_fixed_g_per_hour
                 + 3600.0 * max((g for row in info.carbon_per_request_g
                                 for g in row), default=0.0)
                 for info in gpu_info.values()), default=1.0)
    big_m = 1e4 * (worst + boot_g_per_hour + 1.0)

    c = np.zeros(nvar)
    for ni, n in enumerate(names):
        c[ix(ni)] = gpu_info[n].carbon_fixed_g_per_hour
        if use_boot:
            c[iy(ni)] = boot_g_per_hour
    for p, (ni, bi) in enumerate(pairs):
        b = bkts[bi]
        c[ir(p)] = 3600.0 * gpu_info[names[ni]].carbon_per_request_g[b[0]][b[1]]
    for bi in range(B):
        c[iu(bi)] = big_m

    cons = []
    # rate conservation: sum_n r_{n,b} + u_b = rate_b
    a = np.zeros((B, nvar))
    for p, (ni, bi) in enumerate(pairs):
        a[bi, ir(p)] = 1.0
    for bi in range(B):
        a[bi, iu(bi)] = 1.0
    rhs = np.array([rates[b] for b in bkts])
    cons.append(LinearConstraint(a, rhs, rhs))
    # capacity: sum_b r_{n,b} / tput_{n,b} - x_n <= 0
    a = np.zeros((N, nvar))
    for p, (ni, bi) in enumerate(pairs):
        b = bkts[bi]
        a[ni, ir(p)] = 1.0 / gpu_info[names[ni]].tputs[b[0]][b[1]]
    for ni in range(N):
        a[ni, ix(ni)] = -1.0
    cons.append(LinearConstraint(a, -np.inf, np.zeros(N)))
    # boots: y_n >= x_n - prev_n  <=>  x_n - y_n <= prev_n
    if use_boot:
        a = np.zeros((N, nvar))
        for ni in range(N):
            a[ni, ix(ni)] = 1.0
            a[ni, iy(ni)] = -1.0
        cons.append(LinearConstraint(
            a, -np.inf, np.array([float(prev.get(n, 0)) for n in names])))
    # inventory: per chip, sum_n (chips of n that are c) * x_n <= cap
    if inventory is not None:
        chips = sorted(inventory)
        a = np.zeros((len(chips), nvar))
        for ci, chip in enumerate(chips):
            for ni, n in enumerate(names):
                k = sum(1 for cn in gpu_info[n].chips if cn == chip)
                if k:
                    a[ci, ix(ni)] = float(k)
        cons.append(LinearConstraint(
            a, -np.inf, np.array([float(inventory[ch]) for ch in chips])))

    integrality = np.zeros(nvar)
    integrality[:N] = 1
    try:
        res = milp(c, constraints=cons, integrality=integrality,
                   bounds=Bounds(0, np.inf),
                   options={"time_limit": time_limit_s})
    except Exception:
        return None
    if res.x is None:
        return None

    counts = {names[ni]: int(round(res.x[ix(ni)])) for ni in range(N)
              if int(round(res.x[ix(ni)])) > 0}
    assignment: dict[tuple[int, int], dict[str, float]] = {}
    cap_used: dict[str, float] = {}
    for p, (ni, bi) in enumerate(pairs):
        r = float(res.x[ir(p)])
        if r <= 1e-9:
            continue
        n, b = names[ni], bkts[bi]
        assignment.setdefault(b, {})
        assignment[b][n] = assignment[b].get(n, 0.0) + r
        cap_used[n] = cap_used.get(n, 0.0) + r / gpu_info[n].tputs[b[0]][b[1]]
    unplaced = float(sum(res.x[iu(bi)] for bi in range(B)))
    feasible = unplaced <= 1e-9

    # best-effort dump of residual load, mirroring the greedy fallback:
    # inventory stays hard, SLOs do not
    if not feasible:
        def can_open_lp(n: str) -> bool:
            if inventory is None:
                return True
            used: dict[str, int] = {}
            for m, k in counts.items():
                for cn in gpu_info[m].chips:
                    used[cn] = used.get(cn, 0) + k
            return all(used.get(cn, 0) + sum(1 for c2 in gpu_info[n].chips
                                             if c2 == cn) <= inventory[cn]
                       for cn in gpu_info[n].chips if cn in inventory)

        for bi in range(B):
            r = float(res.x[iu(bi)])
            if r <= 1e-9:
                continue
            openable = [n for n in names if can_open_lp(n)]
            if not openable:
                continue
            fb = max(openable, key=lambda n: max(
                t for row in gpu_info[n].tputs for t in row))
            b = bkts[bi]
            counts[fb] = counts.get(fb, 0) + 1
            assignment.setdefault(b, {})
            assignment[b][fb] = assignment[b].get(fb, 0.0) + r
            unplaced -= r
    unplaced = max(unplaced, 0.0)

    carbon = 0.0
    for n, k in counts.items():
        carbon += k * gpu_info[n].carbon_fixed_g_per_hour
    for b, shares in assignment.items():
        for n, r in shares.items():
            carbon += _dynamic_g_per_hour(gpu_info[n], b, r)
    boot_g = boot_carbon_g * sum(
        max(counts.get(n, 0) - prev.get(n, 0), 0)
        for n in set(counts) | set(prev))
    carbon += boot_g * 3600.0 / window_s
    utilization = {n: cap_used.get(n, 0.0) / counts[n] for n in counts}
    return Allocation(counts, assignment, carbon, feasible, utilization,
                      unplaced_rate=unplaced, boot_g=boot_g, solver="lp")


def allocate(
    workload_distribution: Matrix,
    total_request_rate: float,
    gpu_info: dict[str, InstanceProfile],
    slice_factor: int = 4,
    local_search_rounds: int = 3,
    inventory: Optional[dict[str, int]] = None,
    prev_counts: Optional[dict[str, int]] = None,
    boot_carbon_g: float = 0.0,
    window_s: float = 3600.0,
    solver: str = "greedy",
    lp_time_limit_s: float = 60.0,
) -> Allocation:
    """Choose instance counts + routing minimizing provisioned carbon/hour.

    Greedy first-fit-decreasing over `slice_factor` slices per bucket, then
    a local search that (a) tries to close each instance by repacking its
    load elsewhere and (b) tries to retype each instance. Deterministic:
    ties break on (carbon, name).

    `solver="lp"` solves the same problem as a mixed-integer program
    (scipy `milp`; see `_allocate_lp`) - a global optimum instead of the
    greedy's local one, worth it on 100+-chip inventories where FFD +
    local search leaves instances stranded (docs/scaling.md has the
    when-to-use guidance and measured frontier). Falls back to greedy
    cleanly when scipy's solver is unavailable or fails inside
    `lp_time_limit_s`; `Allocation.solver` records which backend answered.

    `inventory` caps physical chip counts ({"a100": K, "t4": M}, Mélange
    availability constraints): an instance type consumes one of each chip
    in its profile's `chips`; types with empty `chips` are exempt. When
    limits leave load with no instance at all, the result reports it via
    `feasible=False` + `unplaced_rate` (see `raise_if_unserved`).

    `prev_counts`/`boot_carbon_g`/`window_s` add a switching cost for the
    autoscaler's re-solves: every instance beyond the still-running count
    of its type pays a one-time `boot_carbon_g` surcharge, amortized into
    the hourly objective over the `window_s` the allocation will serve -
    so scaling up for a short cheap-grid window must win back its boot
    carbon within that window."""
    if total_request_rate < 0:
        raise ValueError("negative request rate")
    if not gpu_info:
        raise ValueError("gpu_info is empty")
    if inventory is not None and any(v < 0 for v in inventory.values()):
        raise ValueError(f"negative inventory: {inventory}")
    if boot_carbon_g < 0:
        raise ValueError(f"negative boot_carbon_g: {boot_carbon_g}")
    if window_s <= 0:
        raise ValueError(f"window_s must be positive: {window_s}")
    if solver not in ("greedy", "lp"):
        raise ValueError(f"unknown solver: {solver!r} "
                         f"(expected 'greedy' or 'lp')")
    if solver == "lp":
        lp = _allocate_lp(workload_distribution, total_request_rate, gpu_info,
                          inventory=inventory, prev_counts=prev_counts,
                          boot_carbon_g=boot_carbon_g, window_s=window_s,
                          time_limit_s=lp_time_limit_s)
        if lp is not None:
            return lp
        out = allocate(workload_distribution, total_request_rate, gpu_info,
                       slice_factor=slice_factor,
                       local_search_rounds=local_search_rounds,
                       inventory=inventory, prev_counts=prev_counts,
                       boot_carbon_g=boot_carbon_g, window_s=window_s)
        out.solver = "lp-fallback-greedy"
        return out
    prev = dict(prev_counts) if prev_counts else {}
    boot_g_per_hour = boot_carbon_g * 3600.0 / window_s
    unplaced_rate = 0.0
    mass = sum(c for row in workload_distribution for c in row)
    if mass <= 0:
        return Allocation({}, {}, 0.0, True, {})
    names = sorted(gpu_info)

    # --- inventory helpers ----------------------------------------------
    def n_of_type(pool: "list[_Instance]", n: str) -> int:
        return sum(1 for inst in pool if inst.type_name == n)

    def chips_free(pool: "list[_Instance]") -> Optional[dict[str, float]]:
        """Remaining chip budget, or None when unconstrained."""
        if inventory is None:
            return None
        free = {c: float(k) for c, k in inventory.items()}
        for inst in pool:
            for c in gpu_info[inst.type_name].chips:
                if c in free:
                    free[c] -= 1
        return free

    def can_open(n: str, pool: "list[_Instance]",
                 freeing: "Optional[_Instance]" = None) -> bool:
        """Could one more instance of type `n` start (optionally retyping
        `freeing`, whose chips return to the pool first)?"""
        free = chips_free(pool)
        if free is None:
            return True
        if freeing is not None:
            for c in gpu_info[freeing.type_name].chips:
                if c in free:
                    free[c] += 1
        need: dict[str, int] = {}
        for c in gpu_info[n].chips:
            need[c] = need.get(c, 0) + 1
        return all(free.get(c, math.inf) >= k for c, k in need.items())

    def boot_surcharge(pool: "list[_Instance]", n: str) -> float:
        """Amortized boot carbon if opening one more `n` exceeds the
        still-running count (prev_counts) of that type."""
        if not boot_g_per_hour:
            return 0.0
        return boot_g_per_hour if n_of_type(pool, n) >= prev.get(n, 0) else 0.0

    # --- slices, hardest (fewest feasible types, biggest) first ----------
    slices: list[_Slice] = []
    for i, row in enumerate(workload_distribution):
        for j, frac in enumerate(row):
            rate = frac / mass * total_request_rate
            if rate <= 0:
                continue
            per = rate / slice_factor
            slices.extend(_Slice((i, j), per) for _ in range(slice_factor))
    feasible = True

    def n_feasible(s: _Slice) -> int:
        return sum(gpu_info[n].tputs[s.bucket[0]][s.bucket[1]] > 0 for n in names)

    slices.sort(key=lambda s: (n_feasible(s),
                               -max(_capacity_frac(gpu_info[n], s.bucket, s.rate)
                                    if n_feasible(s) else 0.0
                                    for n in names
                                    if gpu_info[n].tputs[s.bucket[0]][s.bucket[1]] > 0)
                               if n_feasible(s) else 0.0,
                               s.bucket))

    instances: list[_Instance] = []

    def spread(bucket: tuple[int, int], rate: float,
               pool: "list[_Instance]") -> float:
        """Absorb up to `rate` of `bucket` into `pool`'s spare capacity
        (in iteration order); returns the unabsorbed remainder."""
        remaining = rate
        for inst in pool:
            frac_unit = _capacity_frac(gpu_info[inst.type_name], bucket, 1.0)
            if math.isinf(frac_unit):
                continue
            take = min(remaining, max((1.0 - inst.load) / frac_unit, 0.0))
            if take > 1e-12:
                inst.add(bucket, take, take * frac_unit)
                remaining -= take
            if remaining <= 1e-12:
                break
        return remaining

    def place(s: _Slice, pool: list[_Instance]) -> bool:
        """Best-fit into an open instance; open the cheapest new one else."""
        best_open = None
        for inst in pool:
            frac = _capacity_frac(gpu_info[inst.type_name], s.bucket, s.rate)
            if math.isinf(frac) or not inst.fits(frac):
                continue
            # best fit: leave the least slack (packs tightest)
            key = (-(inst.load + frac), inst.type_name)
            if best_open is None or key < best_open[0]:
                best_open = (key, inst, frac)
        if best_open is not None:
            _, inst, frac = best_open
            inst.add(s.bucket, s.rate, frac)
            return True
        candidates = []
        for n in names:
            frac = _capacity_frac(gpu_info[n], s.bucket, s.rate)
            if math.isinf(frac) or frac > 1.0 + 1e-9:
                continue
            if not can_open(n, pool):
                continue
            # amortize the new instance's fixed cost over the capacity this
            # slice consumes - assumes later slices fill the rest, which the
            # close/retype local search corrects when they do not; a boot
            # surcharge (amortized the same way) biases re-solves toward
            # instances that are already running
            cost = (frac * (gpu_info[n].carbon_fixed_g_per_hour
                            + boot_surcharge(pool, n))
                    + _dynamic_g_per_hour(gpu_info[n], s.bucket, s.rate))
            candidates.append((cost, n, frac))
        if not candidates:
            return False
        cost, n, frac = min(candidates)
        inst = _Instance(n)
        inst.add(s.bucket, s.rate, frac)
        pool.append(inst)
        return True

    for s in slices:
        if place(s, instances):
            continue
        # the slice fits no single instance whole: split it - first across
        # the spare room of open instances, then onto fresh instances of
        # the cheapest type that can serve the bucket, filled to capacity
        # (inventory allowing) - before giving up on feasibility
        remaining = spread(
            s.bucket, s.rate,
            sorted(instances, key=lambda x: (x.load, x.type_name)))
        while remaining > 1e-12:
            candidates = []
            for n in names:
                frac_unit = _capacity_frac(gpu_info[n], s.bucket, 1.0)
                if math.isinf(frac_unit) or not can_open(n, instances):
                    continue
                # cost of one unit of rate on a fresh, eventually-full
                # instance of this type
                cost = (frac_unit * (gpu_info[n].carbon_fixed_g_per_hour
                                     + boot_surcharge(instances, n))
                        + _dynamic_g_per_hour(gpu_info[n], s.bucket, 1.0))
                candidates.append((cost, n, frac_unit))
            if not candidates:
                break
            _, n, frac_unit = min(candidates)
            take = min(remaining, 1.0 / frac_unit)
            if take <= 1e-12:       # degenerate tput: cannot make progress
                break
            inst = _Instance(n)
            inst.add(s.bucket, take, take * frac_unit)
            instances.append(inst)
            remaining -= take
        if remaining <= 1e-12:
            continue
        feasible = False
        # best-effort: dump the remainder onto the max-throughput type
        # regardless of SLO - but inventory limits stay hard, so fall back
        # to overloading a running instance, and report truly unservable
        # load via unplaced_rate
        openable = [n for n in names if can_open(n, instances)]
        if openable:
            fallback = max(openable, key=lambda n: max(
                t for row in gpu_info[n].tputs for t in row))
            inst = _Instance(fallback)
            frac = _capacity_frac(gpu_info[fallback], s.bucket, remaining)
            inst.add(s.bucket, remaining,
                     min(frac, 1.0) if math.isfinite(frac) else 1.0)
            instances.append(inst)
            continue
        serving = [inst for inst in instances if math.isfinite(
            _capacity_frac(gpu_info[inst.type_name], s.bucket, 1.0))]
        if serving:
            inst = min(serving, key=lambda x: (x.load, x.type_name))
            inst.add(s.bucket, remaining,
                     _capacity_frac(gpu_info[inst.type_name], s.bucket, remaining))
        else:
            unplaced_rate += remaining

    # --- local search ----------------------------------------------------
    def repack(load: dict[tuple[int, int], float],
               pool: list[_Instance]) -> bool:
        """Try to absorb `load` into `pool` (mutates on success)."""
        staged = [(inst, dict(inst.rates), inst.load) for inst in pool]
        for bucket, rate in sorted(load.items(), key=lambda kv: -kv[1]):
            if spread(bucket, rate, pool) > 1e-12:
                for inst, rates, ld in staged:   # roll back
                    inst.rates, inst.load = rates, ld
                return False
        return True

    for _ in range(local_search_rounds):
        improved = False
        # (a) close instances, emptiest first
        for inst in sorted(instances, key=lambda x: x.load):
            others = [x for x in instances if x is not inst]
            if others and repack(inst.rates, others):
                instances = others
                improved = True
        # (b) retype: move an instance's whole load to a cheaper type
        for inst in instances:
            cur = gpu_info[inst.type_name]
            cur_cost = cur.carbon_fixed_g_per_hour + sum(
                _dynamic_g_per_hour(cur, b, r) for b, r in inst.rates.items())
            if boot_g_per_hour and \
                    n_of_type(instances, inst.type_name) > prev.get(inst.type_name, 0):
                cur_cost += boot_g_per_hour   # this instance is itself a boot
            for n in names:
                if n == inst.type_name:
                    continue
                cand = gpu_info[n]
                fracs = [_capacity_frac(cand, b, r) for b, r in inst.rates.items()]
                if any(math.isinf(f) for f in fracs) or sum(fracs) > 1.0 + 1e-9:
                    continue
                if not can_open(n, instances, freeing=inst):
                    continue
                cost = (cand.carbon_fixed_g_per_hour
                        + boot_surcharge(instances, n)
                        + sum(_dynamic_g_per_hour(cand, b, r)
                              for b, r in inst.rates.items()))
                if cost < cur_cost - 1e-9:
                    inst.type_name, inst.load = n, sum(fracs)
                    cur, cur_cost = cand, cost
                    improved = True
        if not improved:
            break

    # --- summarize -------------------------------------------------------
    counts: dict[str, int] = {}
    assignment: dict[tuple[int, int], dict[str, float]] = {}
    load_by_type: dict[str, float] = {}
    carbon = 0.0
    for inst in instances:
        counts[inst.type_name] = counts.get(inst.type_name, 0) + 1
        load_by_type[inst.type_name] = load_by_type.get(inst.type_name, 0.0) + inst.load
        info = gpu_info[inst.type_name]
        carbon += info.carbon_fixed_g_per_hour
        for bucket, rate in inst.rates.items():
            carbon += _dynamic_g_per_hour(info, bucket, rate)
            assignment.setdefault(bucket, {})
            assignment[bucket][inst.type_name] = \
                assignment[bucket].get(inst.type_name, 0.0) + rate
    utilization = {n: load_by_type.get(n, 0.0) / counts[n] for n in counts}
    boot_g = boot_carbon_g * sum(
        max(counts.get(n, 0) - prev.get(n, 0), 0)
        for n in set(counts) | set(prev))
    carbon += boot_g * 3600.0 / window_s
    if unplaced_rate > 0:
        feasible = False
    return Allocation(counts, assignment, carbon, feasible, utilization,
                      unplaced_rate=unplaced_rate, boot_g=boot_g)


def fleet_assignment(alloc: Allocation, fleet_replicas: Sequence[DisaggConfig],
                     ) -> dict[tuple[int, int], tuple[int, ...]]:
    """Translate routing fractions into `route_bucketed` replica pools."""
    by_type: dict[str, list[int]] = {}
    for idx, cfg in enumerate(fleet_replicas):
        by_type.setdefault(cfg.name, []).append(idx)
    out: dict[tuple[int, int], tuple[int, ...]] = {}
    for bucket, shares in alloc.assignment.items():
        pool = [i for n, r in sorted(shares.items()) if r > 0
                for i in by_type.get(n, [])]
        if pool:
            out[bucket] = tuple(pool)
    return out
