"""SLO-aware scheduler: the paper's component ③ and Algorithm 1 (§4.3).

Two matrices over [configuration, workload] - carbon C and SLO attainment
SLO_att - are completed from partial profiling via collaborative filtering
(ALS low-rank matrix factorization, as in Paragon/Quasar-style resource
management), then for each workload the scheduler picks the minimum-carbon
configuration among those meeting the SLO target, with a priority-driven
fallback when none does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.profiler import ProfileDB


# ---------------------------------------------------------------------------
# Collaborative filtering: masked ALS matrix factorization
# ---------------------------------------------------------------------------
def als_complete(
    m: np.ndarray,
    mask: np.ndarray,
    rank: int = 3,
    iters: int = 60,
    ridge: float = 1e-2,
    seed: int = 0,
) -> np.ndarray:
    """Fill the unobserved entries of `m` (mask=True where observed)."""
    if mask.all():
        return m.copy()
    if not mask.any():
        raise ValueError("collaborative filtering needs at least one observation")
    n, k = m.shape
    rank = max(1, min(rank, min(n, k)))
    rng = np.random.default_rng(seed)
    mean = float(m[mask].mean())
    std = float(m[mask].std()) or 1.0
    z = np.where(mask, (m - mean) / std, 0.0)
    u = rng.normal(scale=0.1, size=(n, rank))
    v = rng.normal(scale=0.1, size=(k, rank))
    eye = ridge * np.eye(rank)
    for _ in range(iters):
        for i in range(n):
            obs = mask[i]
            if obs.any():
                vv = v[obs]
                u[i] = np.linalg.solve(vv.T @ vv + eye, vv.T @ z[i, obs])
        for j in range(k):
            obs = mask[:, j]
            if obs.any():
                uu = u[obs]
                v[j] = np.linalg.solve(uu.T @ uu + eye, uu.T @ z[obs, j])
    filled = (u @ v.T) * std + mean
    return np.where(mask, m, filled)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleDecision:
    workload: str
    config: str
    expected_carbon_g_per_token: float
    expected_slo_attainment: float
    feasible: bool           # False => fallback path was taken
    replicas: int = 0        # provisioned instances of `config` (fleet path)


def collaborative_filtering(db: ProfileDB, rank: int = 3, seed: int = 0):
    c, s, mask = db.matrices()
    c_full = als_complete(c, mask, rank=rank, seed=seed)
    s_full = np.clip(als_complete(s, mask, rank=rank, seed=seed), 0.0, 1.0)
    return c_full, s_full


def schedule(
    db: ProfileDB,
    slo_target: float = 0.9,
    priority: str = "slo",            # 'slo' | 'default'
    default_config: Optional[str] = None,
    rank: int = 3,
    seed: int = 0,
    allocation=None,                  # core.allocator.Allocation (fleet path)
) -> dict[str, ScheduleDecision]:
    """Algorithm 1: per workload, argmin-carbon among SLO-feasible configs.

    Fleet-aware path: with `allocation` (the Mélange-style allocator's
    output, core/allocator.py), the candidate set narrows to the configs
    the fleet actually provisions (count > 0), so per-workload decisions
    land on instances that exist; decisions carry the provisioned replica
    count. Configs absent from the profile matrices are ignored; if the
    allocation provisions none of the profiled configs, this falls back to
    the unconstrained Algorithm 1 over all configs."""
    c, s = collaborative_filtering(db, rank=rank, seed=seed)
    default_config = default_config or db.configs[0]
    counts = dict(getattr(allocation, "counts", None) or {})
    candidates = [i for i, n in enumerate(db.configs) if counts.get(n, 0) > 0] \
        if counts else list(range(len(db.configs)))
    if not candidates:
        candidates = list(range(len(db.configs)))
    cand = np.asarray(candidates)
    out: dict[str, ScheduleDecision] = {}
    for j, w in enumerate(db.workloads):
        feasible = cand[s[cand, j] >= slo_target]
        if feasible.size:
            i = int(feasible[np.argmin(c[feasible, j])])
            ok = True
        else:                         # FallbackStrategy(priority)
            default_i = db.configs.index(default_config)
            if priority == "slo" or default_i not in candidates:
                # 'default' must still land on a provisioned instance; an
                # unprovisioned default falls through to best-SLO-in-fleet
                i = int(cand[np.argmax(s[cand, j])])
            else:
                i = default_i
            ok = False
        out[w] = ScheduleDecision(w, db.configs[i], float(c[i, j]), float(s[i, j]),
                                  ok, replicas=counts.get(db.configs[i], 0))
    return out
