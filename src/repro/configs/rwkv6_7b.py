"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.
RWKV6 "Finch" - data-dependent decay. [arXiv:2404.05892]"""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, lora_dim_decay=64, lora_dim_mix=32),
    tie_embeddings=False,
)
