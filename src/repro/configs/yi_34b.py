"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Llama-arch GQA. [arXiv:2403.04652]"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab_size=64000,
    attn=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128, rope_theta=5e6),
    tie_embeddings=False,
)
