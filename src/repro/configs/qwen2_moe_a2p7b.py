"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=1408,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128, rope_theta=1e6),
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_ff_expert=1408,
        num_shared_experts=4,
        d_ff_shared=5632,  # 4 x 1408 fused
    ),
    tie_embeddings=False,
)
