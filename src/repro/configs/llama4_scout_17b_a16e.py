"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed experts top-1 + 1 llama4-style shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,  # == expert width; MoE replaces the dense FFN every layer
    vocab_size=202048,
    attn=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128, rope_theta=5e5),
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
    ),
    tie_embeddings=False,
)
