"""Architecture registry + per-shape input specs for the dry-run.

Every assigned architecture is selectable by id (``--arch <id>``); each
shape maps to the step function the dry-run lowers:

    train_4k    -> train_step   (seq 4096,  global batch 256)
    prefill_32k -> prefill      (seq 32768, global batch 32)
    decode_32k  -> serve_step   (KV len 32768, global batch 128)
    long_500k   -> serve_step   (KV/state len 524288, global batch 1)

``long_500k`` runs only for the sub-quadratic archs (ssm/hybrid); the 8
pure full-attention archs record a documented skip (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, reduced

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "glm4-9b": "glm4_9b",
    "granite-20b": "granite_20b",
    "yi-34b": "yi_34b",
    "yi-6b": "yi_6b",
    "rwkv6-7b": "rwkv6_7b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-2.7b": "zamba2_2p7b",
    # the paper's own serving models
    "llama-7b": "llama_paper",
    "llama-1b": "llama_paper",
    "llama-300m": "llama_paper",
}

ARCH_IDS = list(_ARCH_MODULES)[:10]  # the 10 assigned architectures
PAPER_MODELS = ["llama-7b", "llama-1b", "llama-300m"]


def get_config(arch: str) -> ModelConfig:
    mod_name = _ARCH_MODULES.get(arch)
    if mod_name is None:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if arch == "llama-1b":
        return mod.LLAMA_1B
    if arch == "llama-300m":
        return mod.LLAMA_300M
    if arch == "llama-7b":
        return mod.LLAMA_7B
    return mod.CONFIG


def get_reduced_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_is_runnable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether (arch x shape) is a lowered cell or a documented skip."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, (
            "skip: 524k-token decode requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (DESIGN.md §4)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns {"batch": ...} for train/prefill kinds and
    {"cache": ..., "tokens": ...} for decode kinds. No device allocation.
    """
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if sp.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.frontend is not None:
            # stubbed modality frontend: precomputed frame/patch embeddings
            batch["embeds"] = sds((b, s, cfg.d_model), dtype)
        else:
            batch["tokens"] = sds((b, s), i32)
        if cfg.attn is not None and cfg.attn.m_rope_sections is not None:
            batch["positions"] = sds((3, b, s), i32)
        if sp.kind == "train":
            batch["labels"] = sds((b, s), i32)
        return {"batch": batch}

    # decode: a cache filled to s tokens plus one new token per sequence
    from repro.models.backbone import init_cache

    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, dtype))
    out = {"cache": cache, "tokens": sds((b,), i32)}
    if cfg.frontend == "audio_frames":
        out["embeds"] = sds((b, cfg.d_model), dtype)
    return out
