"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. M-RoPE (16/24/24 sections over the 64 rotary freqs), dynamic
resolution. The vision tower is a stub - input_specs() feeds precomputed
patch embeddings + 3-stream position ids. [arXiv:2409.12191]"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    attn=AttentionConfig(
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1e6,
        m_rope_sections=(16, 24, 24),
    ),
    frontend="vision_patches",
    tie_embeddings=False,
)
