"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Mamba2 blocks + a shared-weight attention block applied every
6 layers (9 taps). [arXiv:2411.15242]"""
from repro.models.config import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=80, rope_theta=1e4),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk_size=128),
    hybrid_attn_every=6,
    tie_embeddings=True,
)
