"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
Llama-arch code model. [arXiv:2405.04324]"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    d_ff=24576,
    vocab_size=49152,
    attn=AttentionConfig(num_heads=48, num_kv_heads=1, head_dim=128, rope_theta=1e4),
    tie_embeddings=False,
)
