"""The paper's own serving trio (§7.1): Llama 7B target + 1B / 300M drafts.

These drive the GreenLLM reproduction benchmarks (Figs. 2-15): the 7B is
the Standalone/target model on the "new" chip, the 1B/300M are the
speculative-decoding draft models placed on "old" chips.
"""
from repro.models.config import AttentionConfig, ModelConfig

LLAMA_7B = ModelConfig(
    name="llama-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=128, rope_theta=1e4),
    tie_embeddings=False,
)

LLAMA_1B = ModelConfig(
    name="llama-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=5504,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128, rope_theta=1e4),
    tie_embeddings=False,
)

LLAMA_300M = ModelConfig(
    name="llama-300m",
    family="dense",
    num_layers=12,
    d_model=1024,
    d_ff=2816,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64, rope_theta=1e4),
    tie_embeddings=True,
)

CONFIG = LLAMA_7B
