"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048. Decoder-only over EnCodec tokens; the EnCodec frontend is a
stub - input_specs() feeds precomputed frame embeddings. [arXiv:2306.05284]"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    attn=AttentionConfig(num_heads=24, num_kv_heads=24, head_dim=64, rope_theta=1e4),
    frontend="audio_frames",
    tie_embeddings=True,
)
